"""Chaos differential suite + preemption machinery tests (DESIGN.md §13).

Three layers:

  * pool-level: preempt -> spill -> re-admit round-trips, priorities,
    per-tenant quotas, budget-shrink sweeps, structured error context;
  * a simulated decode harness replaying a >=30-seed fault corpus against
    the real ArenaPool (fast — no jax in the loop), asserting the chaos
    invariants: no request lost, instantaneous budget never exceeded,
    surviving tokens bit-equal the fault-free run;
  * the real DecodeServer under handcrafted fault plans (tier-1) and the
    full corpus sweep (nightly ``--runslow``).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import Graph
from repro.core.allocator import pin_transients, resident_bytes
from repro.runtime.chaos import (
    ChaosController,
    FaultPlan,
    FaultSpec,
    TransientExecutorError,
    seeded_corpus,
)
from repro.runtime.pool import ArenaPool, LeaseError, PoolError, SpilledLease


def state_graph(n_cache: int = 3, cache_bytes: int = 400,
                transient_bytes: int = 1200, name: str = "state") -> Graph:
    """``n_cache`` persistent buffers + a two-node transient chain."""
    specs = [dict(name=f"s{i}", op="cache", size_bytes=cache_bytes, preds=[])
             for i in range(n_cache)]
    specs.append(dict(name="h", op="act", size_bytes=transient_bytes // 2,
                      preds=[]))
    specs.append(dict(name="l", op="act", size_bytes=transient_bytes,
                      preds=[len(specs) - 1]))
    specs.append(dict(name="tok", op="act", size_bytes=4,
                      preds=[len(specs) - 1]))
    return Graph.build(specs, name=name)


def alone_bytes(g: Graph, overlap: str = "serial") -> int:
    probe = ArenaPool(1 << 40, overlap=overlap)
    return probe._joint_extent([probe.plan(g)[1]])


def joint_bytes(g: Graph, k: int, overlap: str = "serial") -> int:
    probe = ArenaPool(1 << 40, overlap=overlap)
    plan = probe.plan(g)[1]
    return probe._joint_extent([plan] * k)


# ---------------------------------------------------------------------------
# FaultPlan DSL
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_generate_is_deterministic(self):
        a = FaultPlan.generate(7, n_ticks=40, rate=0.5)
        b = FaultPlan.generate(7, n_ticks=40, rate=0.5)
        assert a.specs == b.specs
        assert FaultPlan.generate(8, n_ticks=40, rate=0.5).specs != a.specs

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("meteor_strike", 1)
        with pytest.raises(ValueError, match="tick must be >= 1"):
            FaultSpec("admission_failure", 0)
        with pytest.raises(ValueError, match="factor"):
            FaultSpec("budget_shrink", 1, factor=0.0)

    def test_specs_sorted_and_queryable(self):
        plan = FaultPlan([FaultSpec("executor_error", 5),
                          FaultSpec("budget_shrink", 2, 0.5),
                          FaultSpec("admission_failure", 5)])
        assert [s.tick for s in plan.specs] == [2, 5, 5]
        assert {s.kind for s in plan.at(5)} == \
            {"admission_failure", "executor_error"}
        assert plan.at(3) == ()
        assert "budget_shrink@2x0.5" in plan.describe()

    def test_corpus_is_seeded_and_nonvacuous(self):
        corpus = seeded_corpus(30, base_seed=0, n_ticks=24, rate=0.3)
        assert len(corpus) == 30
        assert corpus == seeded_corpus(30, base_seed=0, n_ticks=24,
                                       rate=0.3) or \
            [p.specs for p in corpus] == \
            [p.specs for p in seeded_corpus(30, base_seed=0, n_ticks=24,
                                            rate=0.3)]
        # a corpus that injects nothing asserts nothing
        assert sum(len(p) for p in corpus) > 30
        kinds = {s.kind for p in corpus for s in p}
        assert "budget_shrink" in kinds and "admission_failure" in kinds


class TestChaosControllerHooks:
    def test_admission_hook_fires_only_on_armed_tick(self):
        ctl = ChaosController(FaultPlan([FaultSpec("admission_failure", 2)]))
        ctl.begin_tick(1)
        assert not ctl.admission_should_fail()
        ctl.begin_tick(2)
        assert ctl.admission_should_fail()
        assert ctl.admission_should_fail()    # every attempt this tick
        ctl.begin_tick(3)
        assert not ctl.admission_should_fail()
        assert all(s.kind == "admission_failure" for s in ctl.fired)

    def test_executor_error_raises_exactly_once(self):
        ctl = ChaosController(FaultPlan([FaultSpec("executor_error", 1)]))
        ctl.begin_tick(1)
        with pytest.raises(TransientExecutorError):
            ctl.maybe_executor_error()
        ctl.maybe_executor_error()            # disarmed after firing

    def test_corrupt_blob_flips_one_byte_deterministically(self):
        ctl = ChaosController(FaultPlan([FaultSpec("cache_corrupt", 3)]))
        ctl.begin_tick(3)
        blob = bytes(range(256)) * 4
        bad = ctl.corrupt_blob(blob)
        assert len(bad) == len(blob)
        diff = [i for i in range(len(blob)) if bad[i] != blob[i]]
        assert len(diff) == 1
        # pending list consumed: the next read passes through untouched
        assert ctl.corrupt_blob(blob) == blob

    def test_budget_shrink_returned_to_driver(self):
        ctl = ChaosController(FaultPlan([FaultSpec("budget_shrink", 4, 0.5)]))
        assert ctl.begin_tick(1) == ()
        specs = ctl.begin_tick(4)
        assert len(specs) == 1 and specs[0].factor == 0.5


# ---------------------------------------------------------------------------
# Pool: preempt / spill / readmit, priorities, quotas, shrink sweeps
# ---------------------------------------------------------------------------


class TestPreemption:
    def test_preempt_spill_readmit_round_trip_bit_identical(self):
        g = state_graph()
        pool = ArenaPool(1 << 30,
                         alloc_fn=lambda n: np.zeros(n, np.uint8))
        t = pool.submit(g)
        assert t.admitted
        extent = t.lease.resident_extent
        state = (np.arange(extent, dtype=np.uint64) % 251).astype(np.uint8)
        sp = pool.preempt(t.lease, state=state)
        assert t.lease not in pool.leases
        assert sp.spill_bytes == extent
        assert np.array_equal(sp.host_state, state)
        assert pool.preemption_stats.preemptions == 1
        assert pool.preemption_stats.spilled_bytes == extent
        t2 = pool.readmit(sp)
        assert t2.admitted
        restored = np.array(sp.host_state, copy=True)
        assert np.array_equal(restored, state)   # bit-identical decode state
        assert pool.preemption_stats.readmitted == 1

    def test_preempt_frees_bytes_and_drains_queue(self):
        g = state_graph()
        pool = ArenaPool(alone_bytes(g))          # one member max
        t1 = pool.submit(g)
        t2 = pool.submit(g)
        assert t1.admitted and not t2.admitted and pool.queue_len == 1
        pool.poll()
        pool.preempt(t1.lease)
        assert t2.admitted                        # freed bytes drained t2

    def test_preempt_candidate_lowest_priority_youngest(self):
        g = state_graph()
        pool = ArenaPool(1 << 30)
        lo_old = pool.submit(g, priority=1).lease
        hi = pool.submit(g, priority=5).lease
        lo_new = pool.submit(g, priority=1).lease
        assert pool.preempt_candidate() is lo_new  # min prio, youngest rid
        pool.preempt(lo_new)
        assert pool.preempt_candidate() is lo_old
        pool.preempt(lo_old)
        assert pool.preempt_candidate() is hi
        pool.release(hi)
        assert pool.preempt_candidate() is None

    def test_preempt_released_lease_raises_double_free(self):
        g = state_graph()
        pool = ArenaPool(1 << 30)
        t = pool.submit(g)
        pool.release(t.lease)
        with pytest.raises(LeaseError) as ei:
            pool.preempt(t.lease)
        assert ei.value.code == "double_free"

    def test_downgrade_repoints_spill_at_memory_class(self):
        g = state_graph()
        pool = ArenaPool(1 << 30)
        key, plan = pool.plan(g)
        pool.register_pareto(key, {"memory": plan,
                                   "latency": pin_transients(plan)})
        t = pool.submit(g, key=key, klass="latency")
        assert t.admitted and t.lease.key == f"{key}@latency"
        sp = pool.preempt(t.lease)
        assert sp.klass == "latency"
        pool.downgrade(sp, "memory")
        assert sp.klass == "memory" and sp.key == f"{key}@memory"
        assert sp.plan is plan
        t2 = pool.readmit(sp)
        assert t2.admitted and t2.lease.key == f"{key}@memory"
        with pytest.raises(PoolError) as ei:
            pool.downgrade(sp, "turbo")
        assert ei.value.code == "unknown_class"

    def test_readmit_backs_off_until_bytes_free(self):
        g = state_graph()
        pool = ArenaPool(joint_bytes(g, 2))
        t1, t2 = pool.submit(g), pool.submit(g)
        sp = pool.preempt(t1.lease)
        t3 = pool.submit(g)                    # takes the freed slot
        assert t3.admitted
        tr = pool.readmit(sp)                  # pool full again: no slot
        assert not tr.admitted and not tr.rejected
        sp.backoff(tick=3)
        assert sp.attempts == 1 and sp.next_tick == 5
        assert not sp.due(4) and sp.due(5)
        pool.release(t2.lease)
        assert pool.readmit(sp).admitted
        ps = pool.preemption_stats
        assert ps.readmit_attempts == 2 and ps.readmitted == 1

    def test_readmit_rejected_when_budget_shrunk_below_plan(self):
        g = state_graph()
        pool = ArenaPool(1 << 30)
        t = pool.submit(g)
        sp = pool.preempt(t.lease)
        pool.set_budget(16)
        tr = pool.readmit(sp)
        assert tr.rejected and tr.reason_code == "budget"
        assert pool.preemption_stats.readmit_rejections == 1


class TestQuotasAndPriorities:
    def test_tenant_quota_never_fits_rejects_with_code(self):
        g = state_graph()
        pool = ArenaPool(1 << 30, tenant_quotas={"t0": 16})
        t = pool.submit(g, tenant="t0")
        assert t.rejected and t.reason_code == "tenant_quota"
        assert pool.submit(g, tenant="other").admitted   # unconstrained

    def test_quota_blocked_tenant_does_not_block_others(self):
        g = state_graph()
        alone = alone_bytes(g)
        pool = ArenaPool(1 << 30, tenant_quotas={"a": alone})
        ta1 = pool.submit(g, tenant="a")
        assert ta1.admitted
        ta2 = pool.submit(g, tenant="a")     # quota-full: queues
        assert not ta2.admitted and not ta2.rejected
        tb = pool.submit(g, tenant="b")      # other tenant must not wait
        assert tb.admitted
        report = pool.queue_report()
        assert len(report) == 1 and report[0]["tenant"] == "a"
        assert "quota" in report[0]["why"]
        pool.release(ta1.lease)              # quota freed: ta2 drains
        assert ta2.admitted
        assert pool.tenant_usage("a") == alone

    def test_priority_and_tenant_recorded_on_lease(self):
        g = state_graph()
        pool = ArenaPool(1 << 30, tenant_quotas={"vip": 1 << 20})
        t = pool.submit(g, priority=7, tenant="vip")
        assert t.lease.priority == 7 and t.lease.tenant == "vip"


class TestBudgetShrink:
    def test_shrink_sweeps_never_fitting_queue_entries(self):
        g = state_graph()
        alone = alone_bytes(g)
        pool = ArenaPool(joint_bytes(g, 2))
        tickets = [pool.submit(g) for _ in range(4)]
        assert [t.admitted for t in tickets] == [True, True, False, False]
        over = pool.set_budget(alone - 1)     # nothing fits this any more
        assert over > 0                       # members now over budget
        swept = pool.poll_rejected()
        assert {t.rid for t in swept} == {tickets[2].rid, tickets[3].rid}
        assert all(t.reason_code == "budget_shrunk" for t in swept)
        ps = pool.preemption_stats
        assert ps.budget_shrinks == 1 and ps.budget_evictions == 2

    def test_shrink_keeps_still_fitting_queue_entries(self):
        g = state_graph()
        pool = ArenaPool(joint_bytes(g, 2))
        for _ in range(3):
            pool.submit(g)
        assert pool.queue_len == 1
        pool.set_budget(joint_bytes(g, 2) - 1)   # single plan still fits
        assert pool.queue_len == 1 and not pool.poll_rejected()

    def test_grow_drains_queue(self):
        g = state_graph()
        pool = ArenaPool(alone_bytes(g))
        t1, t2 = pool.submit(g), pool.submit(g)
        assert t1.admitted and not t2.admitted
        assert pool.set_budget(1 << 30) == 0
        assert t2.admitted

    def test_negative_budget_structured_error(self):
        pool = ArenaPool(1 << 20)
        with pytest.raises(PoolError) as ei:
            pool.set_budget(-1)
        assert ei.value.code == "bad_budget"
        assert ei.value.context["requested_bytes"] == -1


class TestPoolErrorContext:
    def test_scratch_overflow_carries_numbers(self):
        g = state_graph()
        pool = ArenaPool(alone_bytes(g))
        pool.submit(g)
        with pytest.raises(PoolError) as ei:
            pool.reserve_scratch(1 << 30)
        e = ei.value
        assert e.code == "scratch_overflow"
        assert e.requested_bytes == 1 << 30
        assert e.budget_bytes == pool.budget_bytes
        assert e.reserved_bytes is not None and e.queue_depth == 0
        assert set(e.context) >= {"code", "requested_bytes", "budget_bytes"}

    def test_admission_fault_hook_counts_and_kick_retries(self):
        g = state_graph()
        fail = [True]
        pool = ArenaPool(1 << 30, admission_hook=lambda: fail[0])
        t = pool.submit(g)
        assert not t.admitted and not t.rejected     # transiently blocked
        assert pool.preemption_stats.admission_faults == 1
        fail[0] = False
        pool.kick()
        assert t.admitted


# ---------------------------------------------------------------------------
# Simulated chaos differential suite (>=30-seed corpus, no jax in the loop)
# ---------------------------------------------------------------------------


class SimServer:
    """DecodeServer's scheduling loop with a synthetic deterministic decode.

    State is a real byte array spilled/restored through the real
    ArenaPool; the "decode" is a deterministic elementwise update whose
    tokens depend only on (rid, t) — so any divergence from the
    fault-free run is a scheduling bug, not noise.
    """

    GEN = 6

    def __init__(self, pool: ArenaPool, graph: Graph,
                 chaos: ChaosController | None = None,
                 max_readmit_attempts: int = 5):
        self.pool, self.graph, self.chaos = pool, graph, chaos
        if chaos is not None:
            pool.admission_hook = chaos.admission_should_fail
        self.key, self.plan = pool.plan(graph)
        self.extent = resident_bytes(self.plan)[1]
        self.tick = 0
        self.tickets: dict[int, dict] = {}
        self.active: list[dict] = []
        self.spilled: list[dict] = []
        self.done: list[dict] = []
        self.max_readmit_attempts = max_readmit_attempts
        self.max_over = -(1 << 62)
        self.transient_errors = 0

    def submit(self, rid: int, priority: int = 0,
               tenant: str | None = None) -> None:
        req = dict(rid=rid, tokens=[], t=0, state=None, lease=None,
                   spill=None, priority=priority, tenant=tenant,
                   rejected=False, reject_code="")
        t = self.pool.submit(self.graph, key=self.key, priority=priority,
                             tenant=tenant)
        if t.rejected:
            self._reject(req, t.reason_code)
        else:
            self.tickets[t.rid] = req

    def _fresh_state(self, rid: int) -> np.ndarray:
        return ((np.arange(self.extent, dtype=np.uint64) * (rid + 3))
                % 251).astype(np.uint8)

    def _evolve(self, req: dict) -> None:
        req["state"] = ((req["state"].astype(np.uint64) * 33
                         + req["rid"] + req["t"]) % 256).astype(np.uint8)
        req["t"] += 1
        req["tokens"].append(int(req["state"][:64].sum()))

    def _start(self, ticket) -> None:
        req = self.tickets.pop(ticket.rid)
        req["lease"] = ticket.lease
        if req["spill"] is not None:
            req["state"] = req["spill"].host_state.copy()
            req["spill"] = None
        else:
            req["state"] = self._fresh_state(req["rid"])
        self.active.append(req)

    def _reject(self, req: dict, code: str) -> None:
        req["rejected"], req["reject_code"] = True, code
        req["spill"] = None
        self.done.append(req)

    def _collect_rejected(self) -> None:
        for t in self.pool.poll_rejected():
            req = self.tickets.pop(t.rid, None)
            if req is not None:
                self._reject(req, t.reason_code)

    def _enforce_budget(self) -> None:
        while self.pool.reserved_bytes > self.pool.budget_bytes \
                and self.active:
            victim = min(self.active,
                         key=lambda r: (r["priority"], -r["lease"].rid))
            sp = self.pool.preempt(victim["lease"], state=victim["state"])
            victim["lease"] = victim["state"] = None
            sp.next_tick = self.tick + 1
            victim["spill"] = sp
            self.active.remove(victim)
            self.spilled.append(victim)

    def _retry_spilled(self) -> None:
        still = []
        for req in self.spilled:
            sp = req["spill"]
            if not sp.due(self.tick):
                still.append(req)
                continue
            t = self.pool.readmit(sp)
            if t.rejected:
                self._reject(req, t.reason_code)
            elif t.admitted:
                self.tickets[t.rid] = req
            else:
                sp.backoff(self.tick)
                if sp.attempts >= self.max_readmit_attempts:
                    self._reject(req, "readmit_exhausted")
                else:
                    still.append(req)
        self.spilled = still

    def step(self) -> None:
        self.tick += 1
        shrinks = ()
        if self.chaos is not None:
            shrinks = self.chaos.begin_tick(self.tick)
        self.pool.kick()
        self._collect_rejected()
        for t in self.pool.poll():
            self._start(t)
        for s in shrinks:
            if s.kind == "budget_shrink":
                self.pool.set_budget(
                    max(1, int(self.pool.budget_bytes * s.factor)))
                self._collect_rejected()
                self._enforce_budget()
        self._retry_spilled()
        for t in self.pool.poll():
            self._start(t)
        try:
            if self.chaos is not None:
                self.chaos.maybe_executor_error()
            for req in self.active:
                self._evolve(req)
        except TransientExecutorError:
            self.transient_errors += 1      # state untouched: retry next tick
        still = []
        for req in self.active:
            if req["t"] >= self.GEN:
                self.pool.release(req["lease"])
                req["lease"] = None
                self.done.append(req)
            else:
                still.append(req)
        self.active = still
        self.max_over = max(self.max_over, self.pool.reserved_bytes
                            - self.pool.budget_bytes)

    def run(self, n_req: int, priorities=(0, 1, 2),
            max_ticks: int = 500) -> dict[int, dict]:
        for i in range(n_req):
            self.submit(i, priority=priorities[i % len(priorities)])
        while (self.active or self.tickets or self.spilled) \
                and self.tick < max_ticks:
            self.step()
        assert not (self.active or self.tickets or self.spilled), \
            f"sim did not converge in {max_ticks} ticks"
        return {r["rid"]: r for r in self.done}


N_REQ = 8
CORPUS_SEEDS = 32


def _fault_free_tokens() -> dict[int, list[int]]:
    g = state_graph()
    sim = SimServer(ArenaPool(joint_bytes(g, 3)), g)
    done = sim.run(N_REQ)
    assert all(not r["rejected"] for r in done.values())
    return {rid: r["tokens"] for rid, r in done.items()}


class TestChaosInvariantsSim:
    @pytest.fixture(scope="class")
    def baseline(self):
        return _fault_free_tokens()

    @pytest.mark.parametrize("seed", range(CORPUS_SEEDS))
    def test_corpus_invariants(self, baseline, seed):
        g = state_graph()
        plan = FaultPlan.generate(seed, n_ticks=24, rate=0.35)
        pool = ArenaPool(joint_bytes(g, 3))
        sim = SimServer(pool, g, chaos=ChaosController(plan))
        done = sim.run(N_REQ)
        # invariant 1: no request lost — every submit completed or was
        # rejected with a machine-readable reason code
        assert set(done) == set(range(N_REQ))
        for rid, r in done.items():
            if r["rejected"]:
                assert r["reject_code"], f"rid {rid} rejected without code"
            else:
                # invariant 3: surviving tokens bit-equal the fault-free run
                assert r["tokens"] == baseline[rid], \
                    f"rid {rid} tokens diverged under {plan.describe()}"
        # invariant 2: realized arena bytes never exceeded the
        # instantaneous (post-ladder) budget at any tick boundary
        assert sim.max_over <= 0

    def test_corpus_exercises_the_machinery(self):
        """The corpus must actually fire faults and drive preemptions —
        a quiet corpus would make the invariant suite vacuous."""
        g = state_graph()
        totals = {"fired": 0, "preempted": 0, "readmitted": 0,
                  "faulted_admissions": 0, "rejected": 0}
        for seed in range(CORPUS_SEEDS):
            plan = FaultPlan.generate(seed, n_ticks=24, rate=0.35)
            pool = ArenaPool(joint_bytes(g, 3))
            ctl = ChaosController(plan)
            sim = SimServer(pool, g, chaos=ctl)
            done = sim.run(N_REQ)
            ps = pool.preemption_stats
            totals["fired"] += ctl.n_fired
            totals["preempted"] += ps.preemptions
            totals["readmitted"] += ps.readmitted
            totals["faulted_admissions"] += ps.admission_faults
            totals["rejected"] += sum(r["rejected"] for r in done.values())
        assert totals["fired"] > CORPUS_SEEDS
        assert totals["preempted"] > 0
        assert totals["readmitted"] > 0
        assert totals["faulted_admissions"] > 0


# ---------------------------------------------------------------------------
# The real DecodeServer under injected faults
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    jax = pytest.importorskip("jax")
    import repro.configs as configs
    from repro.models.zoo import build_model

    cfg = configs.smoke("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


PROMPT, GEN = 4, 3


def _serve(smoke_model, *, chaos=None, budget_k=3, n_req=4,
           latency_frac=0.5, **kw):
    from repro.core import plan_shared_arena
    from repro.launch.serve import (
        plan_decode_arena,
        run_server,
        synth_requests,
    )

    _, model, params = smoke_model
    smax = PROMPT + GEN
    plan = plan_decode_arena(model, 1, smax)
    budget = plan_shared_arena([plan["plan"]] * budget_k).arena_bytes
    reqs = synth_requests(n_req, PROMPT, GEN, model.cfg.vocab_size, seed=3,
                          latency_frac=latency_frac, priorities=(0, 1))
    m = run_server(model, params, reqs, smax=smax, budget_bytes=budget,
                   warm=1, chaos=chaos, **kw)
    return reqs, m


def _token_map(reqs) -> dict[int, list[int]]:
    return {r.rid: list(r.tokens) for r in reqs if not r.rejected}


class TestChaosServerReal:
    def test_mid_run_shrink_walks_ladder_and_preserves_tokens(
            self, smoke_model):
        base_reqs, base_m = _serve(smoke_model)
        plan = FaultPlan([FaultSpec("budget_shrink", 2, 0.5)])
        reqs, m = _serve(smoke_model, chaos=ChaosController(plan))
        assert m["budget_shrinks"] == 1
        assert m["min_budget_bytes"] < base_m["budget_bytes"]
        # the shrink forced the ladder to shed bytes by preempting
        assert m["n_preempted"] >= 1
        assert sum(m["ladder"].values()) >= 1
        assert m["spill_bytes"] > 0
        # invariant 2: never over the instantaneous budget at a tick edge
        assert m["max_over_budget_bytes"] <= 0
        # invariant 1: no request lost
        assert m["n_served"] + m["n_rejected"] == len(reqs)
        for r in reqs:
            if r.rejected:
                assert r.reject_code
        # invariant 3: every surviving request's tokens bit-equal the
        # fault-free run — the preempt -> spill -> re-admit round-trip
        # restored decode state exactly
        base_tok = _token_map(base_reqs)
        for rid, toks in _token_map(reqs).items():
            assert toks == base_tok[rid]

    def test_transient_executor_error_is_retried(self, smoke_model):
        base_reqs, _ = _serve(smoke_model)
        plan = FaultPlan([FaultSpec("executor_error", 2)])
        reqs, m = _serve(smoke_model, chaos=ChaosController(plan))
        assert m["transient_errors"] == 1
        assert m["n_served"] == len(reqs)
        assert _token_map(reqs) == _token_map(base_reqs)

    def test_admission_fault_delays_but_loses_nothing(self, smoke_model):
        base_reqs, _ = _serve(smoke_model)
        plan = FaultPlan([FaultSpec("admission_failure", 1),
                          FaultSpec("admission_failure", 2)])
        reqs, m = _serve(smoke_model, chaos=ChaosController(plan))
        assert m["admission_faults"] >= 1
        assert m["n_served"] == len(reqs)
        assert _token_map(reqs) == _token_map(base_reqs)

    def test_generated_corpus_smoke_subset(self, smoke_model):
        """Tier-1 slice of the corpus against the real server (the full
        sweep runs nightly — see the slow test below)."""
        base_reqs, _ = _serve(smoke_model)
        base_tok = _token_map(base_reqs)
        for seed in (0, 1):
            plan = FaultPlan.generate(seed, n_ticks=8, rate=0.4)
            reqs, m = _serve(smoke_model, chaos=ChaosController(plan))
            assert m["n_served"] + m["n_rejected"] == len(reqs)
            assert m["max_over_budget_bytes"] <= 0
            for rid, toks in _token_map(reqs).items():
                assert toks == base_tok[rid], plan.describe()

    @pytest.mark.slow
    def test_generated_corpus_full_sweep(self, smoke_model):
        base_reqs, _ = _serve(smoke_model)
        base_tok = _token_map(base_reqs)
        for seed in range(CORPUS_SEEDS):
            plan = FaultPlan.generate(seed, n_ticks=8, rate=0.4)
            reqs, m = _serve(smoke_model, chaos=ChaosController(plan))
            assert m["n_served"] + m["n_rejected"] == len(reqs)
            assert m["max_over_budget_bytes"] <= 0
            for rid, toks in _token_map(reqs).items():
                assert toks == base_tok[rid], plan.describe()


class TestLadderRegressions:
    """Review regressions: rung 2 must shed scratch even when the members
    alone exceed a just-shrunk budget, the ladder must see leases held by
    admitted-but-unpolled tickets, chaos= must not clobber a caller's
    admission hook, and ``max_readmit_attempts`` means exactly that many
    failed attempts."""

    def _server(self, smoke_model, *, budget_k=3, **kw):
        from repro.core import plan_shared_arena
        from repro.launch.serve import (
            DecodeServer,
            make_pool,
            plan_decode_arena,
        )

        _, model, params = smoke_model
        smax = PROMPT + GEN
        dplan = plan_decode_arena(model, 1, smax)
        budget = plan_shared_arena([dplan["plan"]] * budget_k).arena_bytes
        pool = make_pool(budget)
        server = DecodeServer(model, params, pool, smax=smax, **kw)
        return model, server, pool

    def _drain(self, server, max_steps: int = 200) -> None:
        steps = 0
        while (server.active or server._tickets or server._spilled) \
                and steps < max_steps:
            server.step()
            steps += 1
        assert not (server.active or server._tickets or server._spilled)

    def test_shrink_with_scratch_reserved_sheds_scratch(self, smoke_model):
        from repro.launch.serve import synth_requests

        model, server, pool = self._server(smoke_model)
        reqs = synth_requests(2, PROMPT, GEN, model.cfg.vocab_size, seed=7)
        for r in reqs:
            server.submit(r)
        server.step()
        assert len(server.active) == 2
        # e.g. vmap padding rows, held by the server as a token
        server._scratch_token = pool.reserve_scratch(64)
        members = pool.reserved_bytes - pool.scratch_bytes
        # members alone now exceed the new budget: rung 1 is inert (the
        # requests are classless), so rung 2 must shed the scratch — not
        # crash releasing the token — and rung 3 preempts
        server.set_budget(members - 1)
        assert pool.scratch_bytes == 0
        assert server.ladder["shrink_buckets"] == 1
        assert server.ladder["preempt"] >= 1
        assert pool.reserved_bytes <= pool.budget_bytes
        self._drain(server)
        assert all(len(r.tokens) == GEN for r in reqs if not r.rejected)
        assert server.max_over_budget_bytes <= 0

    def test_set_budget_sees_unpolled_admissions(self, smoke_model):
        from repro.launch.serve import synth_requests

        model, server, pool = self._server(smoke_model, budget_k=2)
        req = synth_requests(1, PROMPT, GEN, model.cfg.vocab_size, seed=9)[0]
        server.submit(req)                    # pool admits immediately...
        assert pool.pending_admissions == 1   # ...but nothing polled yet
        server.set_budget(1)
        # the ladder absorbed the pending admission and preempted its
        # lease: nothing stays over budget, nothing is silently dropped
        assert pool.reserved_bytes <= pool.budget_bytes
        assert server.ladder["preempt"] == 1
        self._drain(server)
        assert req.rejected and req.reject_code == "budget"

    def test_readmit_exhausts_after_exactly_max_attempts(self, smoke_model):
        from repro.launch.serve import synth_requests

        model, server, pool = self._server(smoke_model,
                                           max_readmit_attempts=2)
        req = synth_requests(1, PROMPT, GEN, model.cfg.vocab_size, seed=9)[0]
        server.submit(req)
        server.step()
        assert server.active
        server._preempt_request(server.active[0])
        pool.admission_hook = lambda: True    # admission faulted forever
        for _ in range(32):
            if not server._spilled:
                break
            server._tick += 1
            server._retry_spilled()
        assert req.rejected and req.reject_code == "readmit_exhausted"
        assert req.spill is None
        # max_readmit_attempts=2 permits exactly 2 failed attempts
        assert pool.preemption_stats.readmit_attempts == 2

    def test_backoff_wait_does_not_trip_watchdog(self, smoke_model):
        # regression (PR 10): `_progress_sig` ignored spill backoff state,
        # so the ticks a preempted request spends waiting out its
        # exponential backoff window (2, 4, 8, ... ticks) counted as
        # stagnation and tripped TickWatchdog escalation under a tight
        # stall budget.  Backoff waits are scheduled future work: the run
        # must ride them out and resolve the request (here: exhaust its
        # retries), never raise ServingStallError.
        from repro.launch.serve import synth_requests

        model, server, pool = self._server(smoke_model, stall_ticks=4,
                                           max_readmit_attempts=5)
        req = synth_requests(1, PROMPT, GEN, model.cfg.vocab_size, seed=11)[0]
        server.submit(req)
        server.step()
        assert server.active
        server._preempt_request(server.active[0])
        pool.admission_hook = lambda: True    # every readmit faults
        # the final backoff window (2^4 = 16 ticks) dwarfs stall_ticks=4;
        # pre-fix this raised ServingStallError mid-wait
        m = server.run([])
        assert req.rejected and req.reject_code == "readmit_exhausted"
        assert m["watchdog"]["escalations"] == 0

    def test_chaos_refuses_to_clobber_admission_hook(self, smoke_model):
        from repro.launch.serve import (
            DecodeServer,
            make_pool,
            plan_decode_arena,
        )

        _, model, params = smoke_model
        smax = PROMPT + GEN
        dplan = plan_decode_arena(model, 1, smax)
        pool = make_pool(4 * dplan["arena_bytes"])
        pool.admission_hook = lambda: False
        with pytest.raises(ValueError, match="admission_hook"):
            DecodeServer(model, params, pool, smax=smax,
                         chaos=ChaosController(FaultPlan()))


class TestWatchdogAndStallDiagnostics:
    def test_stall_error_carries_structured_report(self, smoke_model):
        from repro.launch.serve import (
            DecodeServer,
            ServingStallError,
            make_pool,
            plan_decode_arena,
            synth_requests,
        )

        _, model, params = smoke_model
        smax = PROMPT + GEN
        plan = plan_decode_arena(model, 1, smax)
        pool = make_pool(4 * plan["arena_bytes"])
        server = DecodeServer(model, params, pool, smax=smax)
        # a hook that always fails models a broken allocator: the queue can
        # provably never drain, and the server must escalate with the
        # queued requests' identities and _fits reasons — not just a count
        pool.admission_hook = lambda: True
        reqs = synth_requests(2, PROMPT, GEN, model.cfg.vocab_size, seed=5,
                              latency_frac=0.5, priorities=(2, 9))
        with pytest.raises(ServingStallError) as ei:
            server.run(reqs)
        e = ei.value
        assert "serving stalled" in str(e)
        assert len(e.report["queued"]) == 2
        q0 = e.report["queued"][0]
        assert {"rid", "klass", "priority", "tenant", "why"} <= set(q0)
        assert q0["why"] == "admissible"      # bytes fit; the hook blocked
        assert f"rid={q0['rid']}" in str(e)
        assert e.report["budget_bytes"] == pool.budget_bytes
        assert server.last_stall is e.report

    def test_watchdog_escalates_after_stall_ticks(self, smoke_model):
        from repro.launch.serve import (
            DecodeServer,
            ServingStallError,
            make_pool,
            plan_decode_arena,
            synth_requests,
        )

        _, model, params = smoke_model
        smax = PROMPT + GEN
        plan = plan_decode_arena(model, 1, smax)
        pool = make_pool(4 * plan["arena_bytes"])
        # chaos present: the provably-stalled fast path defers to the
        # watchdog, which must escalate after stall_ticks quiet ticks
        chaos = ChaosController(FaultPlan())
        server = DecodeServer(model, params, pool, smax=smax, chaos=chaos,
                              stall_ticks=5)
        pool.admission_hook = lambda: True
        reqs = synth_requests(1, PROMPT, GEN, model.cfg.vocab_size, seed=5)
        with pytest.raises(ServingStallError):
            server.run(reqs)
        assert server.watchdog.escalations == 1
        assert server.watchdog.ticks == 5

    def test_step_deadline_misses_counted(self, smoke_model):
        from repro.launch.serve import (
            DecodeServer,
            make_pool,
            plan_decode_arena,
            synth_requests,
        )

        _, model, params = smoke_model
        smax = PROMPT + GEN
        plan = plan_decode_arena(model, 1, smax)
        pool = make_pool(4 * plan["arena_bytes"])
        server = DecodeServer(model, params, pool, smax=smax,
                              step_deadline_s=0.0)   # every tick misses
        reqs = synth_requests(2, PROMPT, GEN, model.cfg.vocab_size, seed=5)
        m = server.run(reqs)
        assert m["n_served"] == 2
        assert m["watchdog"]["deadline_misses"] == m["watchdog"]["ticks"]
        assert m["watchdog"]["ticks"] == m["steps"]

    def test_watchdog_observe_unit(self):
        from repro.launch.serve import TickWatchdog

        wd = TickWatchdog(step_deadline_s=1.0, stall_ticks=3)
        assert not wd.observe(0.1, progressed=True)
        assert wd.deadline_misses == 0
        assert not wd.observe(2.0, progressed=False)
        assert wd.deadline_misses == 1 and wd.slowest_tick_s == 2.0
        assert not wd.observe(0.1, progressed=False)
        assert wd.observe(0.1, progressed=False)     # 3rd quiet tick
        assert wd.escalations == 1 and wd.stagnant_ticks == 0
