"""Arena allocator invariants + Belady traffic model."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    Graph,
    dp_schedule,
    kahn_schedule,
    plan_arena,
    plan_arena_best,
    simulate_traffic,
)
from repro.core.allocator import (
    _build_items,
    _exhaustive_pack,
    _plan_arena_reference,
)
from tests.test_property_scheduler import random_dags

POLICIES = ("first_fit", "best_fit", "greedy_by_size", "best")


def _overlaps(a, b):
    time = not (a.t_free <= b.t_alloc or b.t_free <= a.t_alloc)
    space = not (a.offset + a.size <= b.offset or
                 b.offset + b.size <= a.offset)
    return time and space


@pytest.mark.parametrize("policy", POLICIES)
@given(g=random_dags(max_nodes=12))
@settings(max_examples=40, deadline=None)
def test_arena_no_overlap_and_bounds(policy, g):
    """No two allocations may overlap in (lifetime x offset) space."""
    order = kahn_schedule(g).order
    plan = plan_arena(g, order, policy=policy)
    allocs = plan.allocations
    for i, a in enumerate(allocs):
        assert a.offset >= 0
        assert a.offset + a.size <= plan.arena_bytes
        for b in allocs[i + 1:]:
            assert not _overlaps(a, b), (a, b)


@given(random_dags(max_nodes=12))
@settings(max_examples=40, deadline=None)
def test_arena_at_least_peak(g):
    from repro.core import simulate_schedule

    order = kahn_schedule(g).order
    plan = plan_arena(g, order)
    sim = simulate_schedule(g, order)
    # the arena can fragment but never beats the liveness lower bound
    assert plan.arena_bytes >= sim.peak_bytes - max(g.sizes)
    # the plan's own interval peak is the exact packing lower bound
    assert plan.arena_bytes >= plan.peak_bytes


@given(random_dags(max_nodes=12))
@settings(max_examples=40, deadline=None)
def test_sweep_packers_match_reference(g):
    """The event-driven sweep reproduces the seed allocator's offsets."""
    order = kahn_schedule(g).order
    for policy in ("first_fit", "best_fit"):
        ref = _plan_arena_reference(g, order, policy=policy)
        new = plan_arena(g, order, policy=policy)
        assert new.arena_bytes == ref.arena_bytes, policy
        assert [a.offset for a in new.allocations] == \
            [a.offset for a in ref.allocations], policy


@given(random_dags(max_nodes=12))
@settings(max_examples=40, deadline=None)
def test_best_policy_never_loses(g):
    """plan_arena_best <= every individual policy (first_fit in particular)."""
    order = kahn_schedule(g).order
    best = plan_arena_best(g, order)
    assert best.arena_bytes >= best.peak_bytes
    for policy in ("first_fit", "best_fit", "greedy_by_size"):
        assert best.arena_bytes <= plan_arena(g, order, policy=policy
                                              ).arena_bytes, policy


@given(random_dags(max_nodes=7))
@settings(max_examples=30, deadline=None)
def test_best_matches_bruteforce_packing_on_small_graphs(g):
    """Whenever a brute-forced packing is fragmentation-free, the selected
    plan must be too: arena_bytes > peak_bytes never holds when avoidable."""
    order = kahn_schedule(g).order
    best = plan_arena_best(g, order)
    items = _build_items(g, order, ())
    if len(items) <= 6:
        brute = _exhaustive_pack(items, stop_at=best.peak_bytes)
        assert best.arena_bytes <= brute
        if brute == best.peak_bytes:
            assert best.arena_bytes == best.peak_bytes


def test_policy_alias_and_unknown_policy():
    g = Graph.build([
        dict(name="a", op="input", size_bytes=8),
        dict(name="b", op="op", size_bytes=16, preds=[0]),
    ])
    order = kahn_schedule(g).order
    # best_fit_coalesce is a documented synonym of best_fit
    a = plan_arena(g, order, policy="best_fit_coalesce")
    b = plan_arena(g, order, policy="best_fit")
    assert a.arena_bytes == b.arena_bytes
    assert [x.offset for x in a.allocations] == \
        [x.offset for x in b.allocations]
    with pytest.raises(ValueError, match="unknown arena policy"):
        plan_arena(g, order, policy="nope")
    with pytest.raises(ValueError, match="unknown arena policy"):
        plan_arena_best(g, order, policies=("best",))


@given(random_dags(max_nodes=12))
@settings(max_examples=30, deadline=None)
def test_offset_index_matches_allocations(g):
    order = kahn_schedule(g).order
    plan = plan_arena_best(g, order)
    for a in plan.allocations:
        for nid in a.node_ids:
            assert plan.offset_of(nid) == a.offset
            assert plan.allocation_of(nid) is a
    with pytest.raises(KeyError):
        plan.offset_of(len(g) + 5)


def chain(n=6, size=100):
    specs = [dict(name="n0", op="input", size_bytes=size)]
    for i in range(1, n):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=size,
                          preds=[i - 1], weight_bytes=10))
    return Graph.build(specs)


def test_traffic_zero_when_fits():
    g = chain()
    order = kahn_schedule(g).order
    r = simulate_traffic(g, order, capacity_bytes=10_000,
                         include_weights=False)
    assert r.read_bytes == 0 and r.write_bytes == 0
    assert r.fits_entirely


def test_traffic_positive_when_tight():
    # diamond with long-lived branch output forces spills at tiny capacity
    specs = [
        dict(name="in", op="input", size_bytes=100),
        dict(name="a", op="op", size_bytes=100, preds=[0]),
        dict(name="b", op="op", size_bytes=100, preds=[0]),
        dict(name="c", op="op", size_bytes=100, preds=[1, 2]),
    ]
    g = Graph.build(specs)
    order = kahn_schedule(g).order
    r = simulate_traffic(g, order, capacity_bytes=250,
                         include_weights=False)
    assert r.total_bytes > 0
    assert not r.fits_entirely


def test_traffic_monotone_in_capacity():
    g = chain(8, 100)
    order = kahn_schedule(g).order
    prev = None
    for cap in (150, 250, 450, 900):
        t = simulate_traffic(g, order, cap, include_weights=False).total_bytes
        if prev is not None:
            assert t <= prev
        prev = t


def test_traffic_eradicated_at_dp_peak():
    """Regression for the paper's 'eradicated' case (Fig. 11): at a capacity
    equal to the DP-optimal peak, the DP order incurs exactly zero traffic
    while the Kahn order (4x the liveness peak) must spill."""
    specs = [dict(name="in", op="input", size_bytes=10)]
    for i in range(4):
        specs.append(dict(name=f"e{i}", op="op", size_bytes=1000, preds=[0]))
        specs.append(dict(name=f"p{i}", op="op", size_bytes=10,
                          preds=[len(specs) - 1]))
    g = Graph.build(specs)
    dp = dp_schedule(g)
    cap = dp.peak_bytes
    r_dp = simulate_traffic(g, dp.order, cap, include_weights=False)
    assert r_dp.total_bytes == 0
    assert r_dp.fits_entirely
    r_kahn = simulate_traffic(g, kahn_schedule(g).order, cap,
                              include_weights=False)
    assert r_kahn.total_bytes > 0
    assert not r_kahn.fits_entirely


def test_weight_traffic_constant_across_schedules():
    g = chain(6, 10)
    a = simulate_traffic(g, kahn_schedule(g).order, 10**9).weight_read_bytes
    from repro.core import dp_schedule

    b = simulate_traffic(g, dp_schedule(g).order, 10**9).weight_read_bytes
    assert a == b == 50
