"""Arena allocator invariants + Belady traffic model."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Graph, kahn_schedule, plan_arena, simulate_traffic
from tests.test_property_scheduler import random_dags


def _overlaps(a, b):
    time = not (a.t_free <= b.t_alloc or b.t_free <= a.t_alloc)
    space = not (a.offset + a.size <= b.offset or
                 b.offset + b.size <= a.offset)
    return time and space


@given(random_dags(max_nodes=12))
@settings(max_examples=60, deadline=None)
def test_arena_no_overlap_and_bounds(g):
    order = kahn_schedule(g).order
    plan = plan_arena(g, order)
    allocs = plan.allocations
    for i, a in enumerate(allocs):
        assert a.offset >= 0
        assert a.offset + a.size <= plan.arena_bytes
        for b in allocs[i + 1:]:
            assert not _overlaps(a, b), (a, b)


@given(random_dags(max_nodes=12))
@settings(max_examples=40, deadline=None)
def test_arena_at_least_peak(g):
    from repro.core import simulate_schedule

    order = kahn_schedule(g).order
    plan = plan_arena(g, order)
    sim = simulate_schedule(g, order)
    # the arena can fragment but never beats the liveness lower bound
    assert plan.arena_bytes >= sim.peak_bytes - max(g.sizes)


def chain(n=6, size=100):
    specs = [dict(name="n0", op="input", size_bytes=size)]
    for i in range(1, n):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=size,
                          preds=[i - 1], weight_bytes=10))
    return Graph.build(specs)


def test_traffic_zero_when_fits():
    g = chain()
    order = kahn_schedule(g).order
    r = simulate_traffic(g, order, capacity_bytes=10_000,
                         include_weights=False)
    assert r.read_bytes == 0 and r.write_bytes == 0
    assert r.fits_entirely


def test_traffic_positive_when_tight():
    # diamond with long-lived branch output forces spills at tiny capacity
    specs = [
        dict(name="in", op="input", size_bytes=100),
        dict(name="a", op="op", size_bytes=100, preds=[0]),
        dict(name="b", op="op", size_bytes=100, preds=[0]),
        dict(name="c", op="op", size_bytes=100, preds=[1, 2]),
    ]
    g = Graph.build(specs)
    order = kahn_schedule(g).order
    r = simulate_traffic(g, order, capacity_bytes=250,
                         include_weights=False)
    assert r.total_bytes > 0
    assert not r.fits_entirely


def test_traffic_monotone_in_capacity():
    g = chain(8, 100)
    order = kahn_schedule(g).order
    prev = None
    for cap in (150, 250, 450, 900):
        t = simulate_traffic(g, order, cap, include_weights=False).total_bytes
        if prev is not None:
            assert t <= prev
        prev = t


def test_weight_traffic_constant_across_schedules():
    g = chain(6, 10)
    a = simulate_traffic(g, kahn_schedule(g).order, 10**9).weight_read_bytes
    from repro.core import dp_schedule

    b = simulate_traffic(g, dp_schedule(g).order, 10**9).weight_read_bytes
    assert a == b == 50
