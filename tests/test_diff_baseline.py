"""Tests for the benchmark-baseline differ (benchmarks/diff_baseline.py).

The differ had no tests of its own before the frontier rows landed; these
pin its three comparison regimes — exact deterministic metrics, unit-aware
duration tripwires, and the structural per-point frontier diff (DESIGN.md
§12) — against hand-built baseline/smoke JSON pairs, by counting and
matching the warnings it prints.
"""

from __future__ import annotations

import importlib.util
import json
import os

import pytest

_DIFFER = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "diff_baseline.py")
spec = importlib.util.spec_from_file_location("diff_baseline", _DIFFER)
diff_baseline = importlib.util.module_from_spec(spec)
spec.loader.exec_module(diff_baseline)


def _run(capsys, base_rows, new_rows, tmp_path) -> list[str]:
    """Drive diff_baseline.main() over two row dicts; return output lines."""
    bp, np_ = tmp_path / "base.json", tmp_path / "new.json"
    bp.write_text(json.dumps(
        {"rows": [{"name": k, "us_per_call": 0.0, "derived": v}
                  for k, v in base_rows.items()]}))
    np_.write_text(json.dumps(
        {"rows": [{"name": k, "us_per_call": 0.0, "derived": v}
                  for k, v in new_rows.items()]}))
    import sys
    old = sys.argv
    sys.argv = ["diff_baseline.py", str(bp), str(np_)]
    try:
        diff_baseline.main()
    finally:
        sys.argv = old
    return capsys.readouterr().out.splitlines()


def _warnings(lines) -> list[str]:
    return [ln for ln in lines if ln.startswith("::warning::")]


# ---------------------------------------------------------------------------
# pre-existing regimes (previously untested)
# ---------------------------------------------------------------------------


def test_identical_rows_no_warnings(capsys, tmp_path):
    rows = {"peak_memory/x": "peak_bytes=100;policy=first_fit;wall_s=1.0"}
    out = _run(capsys, rows, dict(rows), tmp_path)
    assert not _warnings(out)


def test_deterministic_drift_warns(capsys, tmp_path):
    out = _run(capsys,
               {"peak_memory/x": "peak_bytes=100"},
               {"peak_memory/x": "peak_bytes=101"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "peak_bytes drifted 100 -> 101" in w[0]


def test_timing_drift_exempt_but_2x_tripwired(capsys, tmp_path):
    # small drift in a duration: silent; >2x above the floor: warns
    out = _run(capsys,
               {"scheduling_time/x": "cold_ms=100.0"},
               {"scheduling_time/x": "cold_ms=120.0"}, tmp_path)
    assert not _warnings(out)
    out = _run(capsys,
               {"scheduling_time/x": "cold_ms=100.0"},
               {"scheduling_time/x": "cold_ms=250.0"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "regressed >2x" in w[0]


def test_disappeared_metric_and_row_warn(capsys, tmp_path):
    out = _run(capsys,
               {"a/x": "peak_bytes=1;n=2", "a/y": "peak_bytes=3"},
               {"a/x": "peak_bytes=1"}, tmp_path)
    w = _warnings(out)
    assert any("metric n disappeared" in x for x in w)
    assert any("row disappeared" in x for x in w)
    # a new metric/row is a note, never a warning
    out = _run(capsys,
               {"a/x": "peak_bytes=1"},
               {"a/x": "peak_bytes=1;extra=7", "a/z": "peak_bytes=9"},
               tmp_path)
    assert not _warnings(out)
    assert any("new metric" in ln for ln in out)
    assert any("new row" in ln for ln in out)


# ---------------------------------------------------------------------------
# structural frontier diffing (DESIGN.md §12)
# ---------------------------------------------------------------------------


def test_identical_frontier_no_warnings(capsys, tmp_path):
    rows = {"peak_memory/frontier_c":
            "frontier=100:500|200:400|300:300;n_points=3"}
    out = _run(capsys, rows, dict(rows), tmp_path)
    assert not _warnings(out)


def test_frontier_peak_drift_warns_per_point(capsys, tmp_path):
    out = _run(capsys,
               {"peak_memory/frontier_c": "frontier=100:500|200:400"},
               {"peak_memory/frontier_c": "frontier=100:500|200:444"},
               tmp_path)
    w = _warnings(out)
    assert len(w) == 1
    assert "point 1 peak drifted 400 -> 444" in w[0]


def test_frontier_surrogate_latency_exact_diffs(capsys, tmp_path):
    # surrogate makespans are deterministic: any drift warns
    out = _run(capsys,
               {"peak_memory/frontier_c": "frontier=100:500"},
               {"peak_memory/frontier_c": "frontier=101:500"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "point 0 latency drifted 100 -> 101" in w[0]


def test_frontier_measured_latency_noise_floored(capsys, tmp_path):
    # measured 'ms' latencies: small drift silent, >2x above floor warns,
    # peaks in the same points still exact-diff
    out = _run(capsys,
               {"serving/pareto_classes": "frontier=100.0ms:500|80.0ms:400"},
               {"serving/pareto_classes": "frontier=130.0ms:500|90.0ms:400"},
               tmp_path)
    assert not _warnings(out)
    out = _run(capsys,
               {"serving/pareto_classes": "frontier=100.0ms:500|80.0ms:400"},
               {"serving/pareto_classes": "frontier=250.0ms:500|90.0ms:444"},
               tmp_path)
    w = _warnings(out)
    assert len(w) == 2
    assert any("point 0 latency regressed >2x" in x for x in w)
    assert any("point 1 peak drifted 400 -> 444" in x for x in w)


def test_frontier_below_noise_floor_never_warns(capsys, tmp_path):
    # 10x regression, but under the 50ms floor: jitter, not signal
    out = _run(capsys,
               {"serving/x": "frontier=1.0ms:500"},
               {"serving/x": "frontier=10.0ms:500"}, tmp_path)
    assert not _warnings(out)


def test_frontier_shape_change_warns(capsys, tmp_path):
    out = _run(capsys,
               {"peak_memory/frontier_c": "frontier=100:500|200:400"},
               {"peak_memory/frontier_c": "frontier=100:500"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "changed shape: 2 -> 1 points" in w[0]


def test_frontier_kind_change_warns(capsys, tmp_path):
    # a surrogate latency becoming a measured one is a schema change
    out = _run(capsys,
               {"a/frontier_c": "frontier=100:500"},
               {"a/frontier_c": "frontier=100.0ms:500"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "changed kind" in w[0]


def test_recompute_frontier_ratio_points_exact(capsys, tmp_path):
    # the PR 6 recompute rows use 'x'-suffixed FLOPs ratios: deterministic
    rows = {"peak_memory/pareto_r": "frontier=1.000x:500|1.240x:400"}
    out = _run(capsys, rows, dict(rows), tmp_path)
    assert not _warnings(out)
    out = _run(capsys, rows,
               {"peak_memory/pareto_r": "frontier=1.000x:500|1.300x:400"},
               tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "latency drifted 1.240x -> 1.300x" in w[0]


def test_malformed_frontier_falls_back_to_opaque(capsys, tmp_path):
    # not lat:peak shaped: compared as one opaque value (old behavior)
    out = _run(capsys,
               {"a/frontier_c": "frontier=abc"},
               {"a/frontier_c": "frontier=abd"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "drifted abc -> abd" in w[0]
    out = _run(capsys,
               {"a/frontier_c": "frontier=abc"},
               {"a/frontier_c": "frontier=abc"}, tmp_path)
    assert not _warnings(out)


def test_real_baseline_self_diff_is_clean(capsys, tmp_path):
    """The committed baseline diffed against itself must be silent."""
    base = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_baseline.json")
    if not os.path.exists(base):
        pytest.skip("no committed baseline")
    with open(base) as f:
        rows = {r["name"]: r["derived"]
                for r in json.load(f).get("rows", [])}
    out = _run(capsys, rows, dict(rows), tmp_path)
    assert not _warnings(out)

# ---------------------------------------------------------------------------
# degraded-mode serving rows (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_degraded_byte_watermarks_are_thresholded_not_exact(capsys,
                                                            tmp_path):
    # spill_bytes / min_budget_bytes scale with load: small drift is
    # silent, >2x growth warns — same unit-aware regime as peak_*bytes
    base = {"serving/degraded_shrink":
            "spill_bytes=36100;min_budget_bytes=25740;n_preempted=1"}
    out = _run(capsys, base,
               {"serving/degraded_shrink":
                "spill_bytes=40000;min_budget_bytes=30000;n_preempted=1"},
               tmp_path)
    assert not _warnings(out)
    out = _run(capsys, base,
               {"serving/degraded_shrink":
                "spill_bytes=80000;min_budget_bytes=25740;n_preempted=1"},
               tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "spill_bytes regressed >2x" in w[0]


def test_degraded_counters_still_exact_diff(capsys, tmp_path):
    # the ladder rung counters are deterministic: any drift warns
    out = _run(capsys,
               {"serving/degraded_shrink": "n_preempted=1;ladder_replan=1"},
               {"serving/degraded_shrink": "n_preempted=3;ladder_replan=1"},
               tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "n_preempted drifted 1 -> 3" in w[0]


def test_degraded_latency_keys_keep_duration_tripwire(capsys, tmp_path):
    # p99 under pressure: exempt from exact diff, tripwired above 2x
    out = _run(capsys,
               {"serving/degraded_shrink": "p99_ms=2605.5"},
               {"serving/degraded_shrink": "p99_ms=2900.0"}, tmp_path)
    assert not _warnings(out)
    out = _run(capsys,
               {"serving/degraded_shrink": "p99_ms=2605.5"},
               {"serving/degraded_shrink": "p99_ms=6000.0"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "latency p99_ms regressed >2x" in w[0]


# ---------------------------------------------------------------------------
# fleet SLO rows (DESIGN.md §14)
# ---------------------------------------------------------------------------


def test_fleet_rows_get_latency_tripwire(capsys, tmp_path):
    out = _run(capsys,
               {"fleet/sharded_4x": "wall_s=4.2"},
               {"fleet/sharded_4x": "wall_s=5.0"}, tmp_path)
    assert not _warnings(out)
    out = _run(capsys,
               {"fleet/sharded_4x": "wall_s=4.2"},
               {"fleet/sharded_4x": "wall_s=9.5"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "latency wall_s regressed >2x" in w[0]


def test_latency_to_nan_warns_never_passes_silently(capsys, tmp_path):
    # regression (PR 10): an all-rejected run used to report 0.0 ms and
    # sail through; now it reports NaN, and the differ flags the
    # measured->NaN transition instead of skipping it as timing noise
    out = _run(capsys,
               {"serving/pooled": "p99_ms=120.0"},
               {"serving/pooled": "p99_ms=nan"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "became NaN" in w[0]
    # NaN on both sides is stable, not a fresh regression
    out = _run(capsys,
               {"serving/pooled": "p99_ms=nan"},
               {"serving/pooled": "p99_ms=nan"}, tmp_path)
    assert not _warnings(out)


def test_deterministic_value_to_nan_still_drifts(capsys, tmp_path):
    # NaN leaking into an exact-diffed key must not compare clean
    out = _run(capsys,
               {"fleet/sharded_4x": "p99_ticks=177.0"},
               {"fleet/sharded_4x": "p99_ticks=nan"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "drifted" in w[0]


def test_disappeared_latency_metric_warns(capsys, tmp_path):
    # regression (PR 10): a latency column that vanishes from a serving
    # or fleet row was silently skipped as machine-dependent timing
    out = _run(capsys,
               {"fleet/sharded_4x": "n_served=100;wall_s=4.2"},
               {"fleet/sharded_4x": "n_served=100"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "latency metric wall_s disappeared" in w[0]
    # non-SLO rows keep the old exemption for timing columns
    out = _run(capsys,
               {"peak_memory/x": "peak_bytes=1;wall_s=4.2"},
               {"peak_memory/x": "peak_bytes=1"}, tmp_path)
    assert not _warnings(out)


def test_rejection_rate_slo_thresholds(capsys, tmp_path):
    base = {"fleet/sharded_4x": "rejection_rate=0.0023"}
    # small absolute movement: a note, not a warning
    out = _run(capsys, base,
               {"fleet/sharded_4x": "rejection_rate=0.008"}, tmp_path)
    assert not _warnings(out)
    assert any("within SLO floors" in ln for ln in out)
    # past the absolute AND relative floors: warns
    out = _run(capsys, base,
               {"fleet/sharded_4x": "rejection_rate=0.05"}, tmp_path)
    w = _warnings(out)
    assert len(w) == 1 and "rejection_rate regressed" in w[0]
    # a rise from zero below the absolute floor stays quiet
    out = _run(capsys,
               {"fleet/sharded_4x": "rejection_rate=0.0"},
               {"fleet/sharded_4x": "rejection_rate=0.009"}, tmp_path)
    assert not _warnings(out)
