"""Distribution layer: sharding specs, mini-mesh train/serve parity, and a
subprocess mini dry-run with 8 host devices (the multi-pod pattern at small
scale — the 512-device run is exercised by launch/dryrun.py itself)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.configs.base import ShardingRules
from repro.models.params import ParamDef, param_pspecs
from repro.models.zoo import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_pspec_divisibility_fallback():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    rules = ShardingRules(batch=("data",), fsdp="data", tensor="model")
    defs = {
        "ok": ParamDef((32, 64), ("fsdp", "tensor")),
        "kv": ParamDef((32, 8), ("fsdp", "tensor")),     # 8 % 16 != 0
    }
    specs = param_pspecs(defs, rules, FakeMesh())
    assert specs["ok"] == P("data", "model")
    assert specs["kv"] == P("data")                      # tensor dropped


def test_sequence_axis_takes_leftovers():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 16))

    rules = ShardingRules(batch=("data",), fsdp=None, tensor="model",
                          sequence="model")
    # gemma-like: KV=16 divisible -> heads take 'model', seq replicated
    d16 = ParamDef((2, 8, 1024, 16, 64),
                   (None, "batch", "sequence", "tensor", None))
    # llama-like: KV=8 indivisible -> seq takes 'model'
    d8 = ParamDef((2, 8, 1024, 8, 64),
                  (None, "batch", "sequence", "tensor", None))
    s16 = param_pspecs({"x": d16}, rules, FakeMesh())["x"]
    s8 = param_pspecs({"x": d8}, rules, FakeMesh())["x"]
    assert s16 == P(None, "data", None, "model")
    assert s8 == P(None, "data", "model")


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import repro.configs as C
from repro.launch.mesh import rules_for_mesh
from repro.launch.steps import (make_optimizer, make_train_step,
                                train_input_specs, make_decode_step,
                                serve_input_specs)
from repro.models.zoo import build_model
from repro.configs.base import ShapeConfig
import dataclasses, json

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = rules_for_mesh(mesh)
out = {}
for name in ["llama3.2-1b", "granite-moe-3b-a800m", "rwkv6-7b",
             "recurrentgemma-2b", "seamless-m4t-medium"]:
    cfg = dataclasses.replace(C.smoke(name), scan_unroll=False)
    model = build_model(cfg)
    shape = ShapeConfig("t", 64, 8, "train")
    opt = make_optimizer(cfg)
    step = make_train_step(model, opt, rules)
    specs = train_input_specs(model, opt, shape, mesh, rules)
    with mesh:
        compiled = jax.jit(step, donate_argnums=(0,)).lower(*specs).compile()
        hlo = compiled.as_text()
        dshape = ShapeConfig("d", 64, 8, "decode")
        dstep = make_decode_step(model, rules)
        dspecs = serve_input_specs(model, dshape, mesh, rules, kind="decode")
        dcompiled = jax.jit(dstep, donate_argnums=(1,)).lower(*dspecs).compile()
    out[name] = {
        "train_collectives": sum(hlo.count(f" {c}(") + hlo.count(f" {c}-start(")
            for c in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute")),
        "ok": True,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_mini_multipod_dryrun_subprocess():
    """2x2x2 (pod,data,model) mesh over 8 host devices: lower+compile the
    train and decode steps for 5 family-representative smoke archs."""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert len(out) == 5
    for name, rec in out.items():
        assert rec["ok"], name
        assert rec["train_collectives"] > 0, (
            f"{name}: sharded train step must communicate"
        )


@pytest.mark.slow
def test_train_step_sharded_matches_unsharded():
    """Numerical parity: the same train step on 1 device vs a 2x2 host mesh
    must produce the same loss (pure data/tensor parallel reformulation)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, json
import repro.configs as C
from repro.launch.mesh import rules_for_mesh
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.zoo import build_model

cfg = C.smoke("llama3.2-1b")
model = build_model(cfg)
opt = make_optimizer(cfg)
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params)}
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                            cfg.vocab_size)
batch = {"tokens": tokens}

plain = make_train_step(model, opt, None)
_, m1 = jax.jit(plain)(state, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
rules = rules_for_mesh(mesh)
sharded = make_train_step(model, opt, rules)
with mesh:
    _, m2 = jax.jit(sharded)(state, batch)
print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(out["l1"], out["l2"], rtol=2e-2)
