"""Property-based invariants of the scheduler stack (hypothesis).

Skipped cleanly when hypothesis isn't installed (it is pinned in the
``test`` extra, so CI always runs these).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    Graph,
    brute_force_schedule,
    dp_schedule,
    greedy_schedule,
    kahn_schedule,
    partition,
    simulate_schedule,
)
from repro.core.budget import adaptive_budget_schedule


@st.composite
def random_dags(draw, max_nodes=9):
    n = draw(st.integers(min_value=2, max_value=max_nodes))
    specs = []
    for i in range(n):
        preds = []
        if i > 0:
            k = draw(st.integers(min_value=0, max_value=min(i, 3)))
            preds = sorted(draw(st.sets(
                st.integers(min_value=0, max_value=i - 1),
                min_size=min(k, i), max_size=min(k, i),
            )))
        size = draw(st.integers(min_value=1, max_value=64))
        specs.append(dict(name=f"n{i}", op="op", size_bytes=size,
                          preds=preds))
    return Graph.build(specs)


@given(random_dags())
@settings(max_examples=60, deadline=None)
def test_dp_is_optimal_on_random_dags(g):
    dp = dp_schedule(g)
    bf = brute_force_schedule(g)
    assert dp.peak_bytes == bf.peak_bytes
    assert g.is_topological(dp.order)
    assert simulate_schedule(g, dp.order).peak_bytes == dp.peak_bytes


@given(random_dags())
@settings(max_examples=60, deadline=None)
def test_bnb_pruning_never_changes_optimal_peak(g):
    """Dominance + incumbent + lower-bound pruning are exactness-preserving:
    the bounded search must return the brute-force peak on both engines and
    never expand more states than the unpruned DP."""
    bf = brute_force_schedule(g)
    legacy = dp_schedule(g, engine="python", bnb=False)
    for engine in ("python", "numpy"):
        res = dp_schedule(g, engine=engine, bnb=True)
        assert res.peak_bytes == bf.peak_bytes == legacy.peak_bytes
        assert res.final_bytes == legacy.final_bytes
        assert res.n_states_expanded <= legacy.n_states_expanded
        assert simulate_schedule(g, res.order).peak_bytes == res.peak_bytes


@given(random_dags(max_nodes=11))
@settings(max_examples=40, deadline=None)
def test_hierarchical_schedule_matches_flat_dp(g):
    """Nested-segment-tree scheduling (with in-run cell reuse) concatenates
    to the flat whole-graph DP optimum."""
    from repro.core import schedule_order

    res = schedule_order(g)
    assert g.is_topological(res.order)
    assert simulate_schedule(g, res.order).peak_bytes == \
        dp_schedule(g).peak_bytes


@given(random_dags())
@settings(max_examples=60, deadline=None)
def test_heuristics_never_beat_dp(g):
    opt = dp_schedule(g).peak_bytes
    for fn in (kahn_schedule, greedy_schedule):
        res = fn(g)
        assert res.peak_bytes >= opt
        assert g.is_topological(res.order)


@given(random_dags())
@settings(max_examples=40, deadline=None)
def test_adaptive_budget_finds_optimum(g):
    res, stats = adaptive_budget_schedule(g, state_quota=512)
    opt = dp_schedule(g).peak_bytes
    assert res.peak_bytes == opt
    assert stats.tau_trajectory[-1][1] == "solution"


@given(random_dags(max_nodes=12))
@settings(max_examples=40, deadline=None)
def test_partition_preserves_coverage_and_topology(g):
    segs = partition(g)
    all_ids = sorted(i for s in segs for i in s.node_ids)
    assert all_ids == list(range(len(g)))
    # schedule via pipeline and verify it is a valid topological order
    from repro.core import schedule

    res = schedule(g, rewrite=False, compute_baselines=False,
                   state_quota=512)
    assert g.is_topological(res.order)
    # divide-and-conquer at single-node separators preserves optimality
    assert res.peak_bytes == dp_schedule(g).peak_bytes
