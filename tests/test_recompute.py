"""Rematerialization (DESIGN.md §10): FLOPs model, clone mechanics, and
the peak-vs-FLOPs trade on the paper graphs.

The acceptance bar for PR 6, asserted here at CI-scale search bounds: on
the RandWire cells the recompute planner must reach a peak *strictly
below the exact no-recompute optimum* (>=10% on at least one graph)
within a 1.3x FLOPs budget, the executor must realize exactly the
planned bytes, and the expanded graph's outputs must be bit-equal to the
no-recompute reference.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import Graph, PlanConfig, execute_plan, plan, run_reference
from repro.core.rewriter import (
    RECOMPUTE_EXCLUDED_OPS,
    _clone_out,
    graph_flops,
    node_flops,
    recompute_provenance,
    rematerialize,
)
from repro.graphs import BENCHMARK_GRAPHS


# ---------------------------------------------------------------------------
# Surrogate FLOPs model
# ---------------------------------------------------------------------------


def test_node_flops_exact_for_1x1_conv():
    # 1x1 conv over px=16 pixels, cin=8 -> cout=4: true MACs = px*cin*cout
    px, cin, cout = 16, 8, 4
    g = Graph.build([
        dict(name="x", op="input", size_bytes=4 * px * cin, preds=[]),
        dict(name="y", op="conv", size_bytes=4 * px * cout,
             weight_bytes=4 * cin * cout, preds=[0]),
    ], name="conv1x1")
    assert node_flops(g, 1) == px * cin * cout
    assert node_flops(g, 0) == 0               # inputs cost nothing
    assert graph_flops(g) == px * cin * cout


def test_weightless_op_costs_output_elements():
    g = Graph.build([
        dict(name="x", op="input", size_bytes=256, preds=[]),
        dict(name="r", op="relu", size_bytes=256, preds=[0]),
    ], name="ew")
    assert node_flops(g, 1) == 64              # 256 bytes / 4 per element


# ---------------------------------------------------------------------------
# Clone mechanics and provenance
# ---------------------------------------------------------------------------


def _fanout_graph() -> Graph:
    return Graph.build([
        dict(name="x", op="input", size_bytes=64, preds=[]),
        dict(name="u", op="conv", size_bytes=512, weight_bytes=64,
             preds=[0]),
        dict(name="c1", op="conv", size_bytes=64, preds=[1]),
        dict(name="c2", op="conv", size_bytes=64, preds=[1]),
        dict(name="c3", op="conv", size_bytes=64, preds=[1]),
        dict(name="y", op="add", size_bytes=64, preds=[2, 3, 4]),
    ], name="fanout")


def test_clone_out_rewires_and_tags_provenance():
    g = _fanout_graph()
    gx = _clone_out(g, 1, 2)                   # clone u for c2, c3
    assert len(gx) == len(g) + 2
    # originals keep their ids, names and preds
    for i, nd in enumerate(g.nodes):
        assert gx.nodes[i].name == nd.name
        assert gx.nodes[i].op == nd.op
    # u keeps its earliest consumer; the clones feed the last two
    assert sorted(gx.succs[1]) == [2]
    for ci in (len(g), len(g) + 1):
        nd = gx.nodes[ci]
        assert recompute_provenance(nd) == ("u", 1)
        assert nd.op == "conv" and nd.size_bytes == 512
        assert nd.preds == g.nodes[1].preds
    assert tuple(gx.nodes[3].preds) == (len(g),)
    assert tuple(gx.nodes[4].preds) == (len(g) + 1,)
    assert recompute_provenance(gx.nodes[1]) is None


def test_clone_of_clone_keeps_root_provenance():
    g = _fanout_graph()
    gx = _clone_out(g, 1, 2)
    # cloning u again (it still feeds c1 plus nothing else -> make its pred
    # multi-consumer instead): clone the *input* and check root naming
    gy = _clone_out(gx, 0, 1)
    clone = gy.nodes[len(gx)]
    assert recompute_provenance(clone) == ("x", 0)
    # a clone's own provenance propagates when the clone itself is cloned
    gz = _clone_out(gx, len(g), 1)
    assert recompute_provenance(gz.nodes[len(gx)]) == ("u", 1)


def test_clone_outputs_bit_equal_original():
    g = _fanout_graph()
    gx = _clone_out(g, 1, 2)
    ref, refx = run_reference(g), run_reference(gx)
    assert set(ref) == set(refx)               # same output nodes
    for name, val in ref.items():
        np.testing.assert_array_equal(np.asarray(refx[name]),
                                      np.asarray(val))


# ---------------------------------------------------------------------------
# The search: budget respected, no-gain graphs untouched
# ---------------------------------------------------------------------------


def test_rematerialize_budget_one_is_identity():
    g = _fanout_graph()
    out, rep = rematerialize(g, flops_budget=1.0)
    assert out is g and rep.n_clones == 0
    assert rep.frontier == ((1.0, rep.base_peak_bytes, 0),)
    assert rep.peak_bytes == rep.base_peak_bytes


def test_rematerialize_chain_graph_untouched():
    # a pure chain has no multi-consumer node: nothing to clone
    g = Graph.build(
        [dict(name="x", op="input", size_bytes=64, preds=[])]
        + [dict(name=f"c{i}", op="conv", size_bytes=64, preds=[i])
           for i in range(4)],
        name="chain")
    out, rep = rematerialize(g)
    assert out is g and rep.n_evals == 1


def test_rematerialize_respects_flops_budget():
    for budget in (1.05, 1.3):
        g = BENCHMARK_GRAPHS["randwire_cifar10"]()
        _, rep = rematerialize(g, flops_budget=budget, max_rounds=1)
        assert rep.flops_ratio <= budget + 1e-9
        for ratio, _, _ in rep.frontier:
            assert ratio <= budget + 1e-9


def test_excluded_ops_never_cloned():
    g = BENCHMARK_GRAPHS["randwire_cifar100"]()
    gx, rep = rematerialize(g, max_rounds=2, beam_width=2)
    for nd in gx.nodes[len(g):]:
        assert nd.op not in RECOMPUTE_EXCLUDED_OPS
        assert recompute_provenance(nd) is not None


# ---------------------------------------------------------------------------
# Acceptance: below the exact no-recompute optimum on the paper graphs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,rounds,min_gain", [
    ("randwire_cifar10", 1, 0.05),
    ("randwire_cifar100", 3, 0.10),
])
def test_recompute_beats_exact_baseline(name, rounds, min_gain):
    g = BENCHMARK_GRAPHS[name]()
    base = plan(g, PlanConfig(rewrite=True, state_quota=4000), cache=False)
    assert base.exact, f"{name}: no-recompute baseline must be exact"

    res = plan(g, PlanConfig(rewrite=True, recompute=True,
                             recompute_rounds=rounds, state_quota=4000),
               cache=False)
    rep = res.recompute_report
    assert rep is not None and rep.n_clones > 0
    # strictly below the *exact* optimum of the unexpanded graph, by at
    # least the per-graph bar, within the FLOPs budget
    assert res.peak_bytes < base.peak_bytes
    assert res.peak_bytes <= (1 - min_gain) * base.peak_bytes, (
        f"{name}: {res.peak_bytes} vs exact base {base.peak_bytes} "
        f"(< {min_gain:.0%} gain)")
    assert rep.flops_ratio <= 1.3 + 1e-9

    # the frontier is monotone: ratios increase, peaks strictly decrease,
    # starting at the no-recompute base point
    assert res.pareto_frontier[0] == (1.0, rep.base_peak_bytes, 0)
    ratios = [p[0] for p in res.pareto_frontier]
    peaks = [p[1] for p in res.pareto_frontier]
    assert ratios == sorted(ratios)
    assert all(a > b for a, b in zip(peaks, peaks[1:]))

    # executor realizes exactly the planned bytes on the expanded graph
    ex = execute_plan(res.graph, res.order, res.arena, inputs=None,
                      strict=True)
    assert ex.realized_peak_bytes == res.arena.peak_bytes

    # and the outputs are bit-equal to the no-recompute reference
    ref = run_reference(base.graph)
    assert set(ref) == set(ex.outputs)
    for out_name, val in ref.items():
        np.testing.assert_array_equal(
            np.asarray(ex.outputs[out_name]), np.asarray(val),
            err_msg=f"{name}: recompute output {out_name!r} diverges")
