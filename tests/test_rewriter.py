"""Identity graph rewriting: IR behaviour + numerical identity (Eq. 3-8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Graph,
    annotate_inplace,
    dp_schedule,
    kahn_schedule,
    rewrite_graph,
    simulate_schedule,
)


def concat_conv_graph(n_branches=4, branch_kb=100, out_kb=120):
    specs = [dict(name="in", op="input", size_bytes=10_000)]
    outs = []
    for i in range(n_branches):
        specs.append(dict(name=f"b{i}", op="conv",
                          size_bytes=branch_kb * 1024, preds=[0]))
        outs.append(len(specs) - 1)
    specs.append(dict(name="cc", op="concat",
                      size_bytes=n_branches * branch_kb * 1024, preds=outs))
    specs.append(dict(name="conv", op="conv", size_bytes=out_kb * 1024,
                      preds=[len(specs) - 1], weight_bytes=4096))
    return Graph.build(specs)


def test_concat_conv_rewrite_reduces_peak():
    g = concat_conv_graph()
    g2, rep = rewrite_graph(g)
    assert rep.n_concat_conv == 1
    # concat + conv nodes replaced by accumulating partial convs
    assert not any(n.op == "concat" for n in g2.nodes)
    before = dp_schedule(g).peak_bytes
    after = dp_schedule(g2).peak_bytes
    # paper Fig. 9: sum(x_i) + y  ->  max(x_i) + y
    assert after < before


def test_concat_depthconv_rewrite():
    specs = [dict(name="in", op="input", size_bytes=1024)]
    outs = []
    for i in range(3):
        specs.append(dict(name=f"b{i}", op="conv", size_bytes=1024,
                          preds=[0]))
        outs.append(len(specs) - 1)
    specs.append(dict(name="cc", op="concat", size_bytes=3 * 1024,
                      preds=outs))
    specs.append(dict(name="dw", op="depthconv", size_bytes=3 * 1024,
                      preds=[len(specs) - 1]))
    g = Graph.build(specs)
    g2, rep = rewrite_graph(g)
    assert rep.n_concat_depthconv == 1
    assert any(n.op == "concat_view" for n in g2.nodes)
    assert dp_schedule(g2).peak_bytes <= dp_schedule(g).peak_bytes


def test_rewrite_skips_concat_with_multiple_consumers():
    specs = [dict(name="in", op="input", size_bytes=8)]
    specs.append(dict(name="b0", op="conv", size_bytes=8, preds=[0]))
    specs.append(dict(name="b1", op="conv", size_bytes=8, preds=[0]))
    specs.append(dict(name="cc", op="concat", size_bytes=16, preds=[1, 2]))
    specs.append(dict(name="conv", op="conv", size_bytes=8, preds=[3]))
    specs.append(dict(name="other", op="relu", size_bytes=16, preds=[3]))
    g = Graph.build(specs)
    g2, rep = rewrite_graph(g)
    assert rep.total == 0      # concat has 2 consumers -> must materialize


# ------------------------------------------------------ in-place annotation

def test_inplace_unary_chain_shares_one_buffer():
    # conv -> relu -> bn: the elementwise tail aliases through to the conv
    # output, so the chain costs one buffer instead of three
    specs = [
        dict(name="in", op="input", size_bytes=64),
        dict(name="c", op="conv", size_bytes=128, preds=[0]),
        dict(name="r", op="relu", size_bytes=128, preds=[1]),
        dict(name="b", op="bn", size_bytes=128, preds=[2]),
    ]
    g = Graph.build(specs)
    g2, n = annotate_inplace(g)
    assert n == 2
    assert g2.nodes[2].alias_preds == frozenset({1})
    assert g2.nodes[3].alias_preds == frozenset({2})
    # footprint model: relu/bn allocate nothing on top of the conv output
    assert dp_schedule(g2).peak_bytes == 64 + 128
    assert dp_schedule(g).peak_bytes == 128 + 128
    # and the arena fuses the chain into a single allocation
    from repro.core import plan_arena

    plan = plan_arena(g2, g2.topo_order())
    chain = plan.allocation_of(1)
    assert chain.node_ids == [1, 2, 3]


def test_inplace_skips_inputs_multi_consumers_and_size_mismatch():
    specs = [
        dict(name="in", op="input", size_bytes=32),
        dict(name="r0", op="relu", size_bytes=32, preds=[0]),     # pred=input
        dict(name="c", op="conv", size_bytes=32, preds=[1]),
        dict(name="r1", op="relu", size_bytes=16, preds=[2]),     # size differs
        dict(name="r2", op="relu", size_bytes=32, preds=[2]),     # c has 2 uses
        dict(name="out", op="op", size_bytes=8, preds=[3, 4]),
    ]
    g = Graph.build(specs)
    g2, n = annotate_inplace(g)
    assert n == 0
    assert g2 is g                    # untouched graph returned as-is


def test_inplace_accumulating_add_aliases_one_operand():
    specs = [
        dict(name="in", op="input", size_bytes=16),
        dict(name="a", op="conv", size_bytes=64, preds=[0]),
        dict(name="b", op="conv", size_bytes=64, preds=[0]),
        dict(name="s", op="add", size_bytes=64, preds=[1, 2]),
    ]
    g = Graph.build(specs)
    g2, n = annotate_inplace(g)
    assert n == 1
    assert g2.nodes[3].alias_preds == frozenset({1})
    # the sum accumulates into a's buffer instead of a third feature map
    assert dp_schedule(g).peak_bytes == 16 + 64 + 64 + 64 - 16
    assert dp_schedule(g2).peak_bytes == 16 + 64 + 64


def test_inplace_composes_with_pipeline():
    from repro.core import schedule
    from repro.graphs import darts_normal_cell

    g = darts_normal_cell()
    with_ip = schedule(g, state_quota=4000, cache=False,
                       compute_baselines=False)
    without = schedule(g, state_quota=4000, inplace=False, cache=False,
                       compute_baselines=False)
    assert with_ip.rewrite_report.n_inplace > 0
    assert with_ip.peak_bytes <= without.peak_bytes
    assert with_ip.arena_bytes <= without.arena_bytes


# ---------------------------------------------------------------- numerics

def test_channelwise_partition_numeric_identity():
    """Eq. 3-6: conv(concat(x1..xk)) == sum_i partial_conv(x_i)."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    xs = [jax.random.normal(ks[i], (1, 8, 8, 3)) for i in range(3)]
    w = jax.random.normal(ks[3], (3, 3, 9, 4))    # HWIO, I = 3 branches x 3

    dn = jax.lax.conv_dimension_numbers(
        (1, 8, 8, 9), w.shape, ("NHWC", "HWIO", "NHWC")
    )
    full = jax.lax.conv_general_dilated(
        jnp.concatenate(xs, -1), w, (1, 1), "SAME", dimension_numbers=dn
    )
    dn_p = jax.lax.conv_dimension_numbers(
        (1, 8, 8, 3), (3, 3, 3, 4), ("NHWC", "HWIO", "NHWC")
    )
    parts = [
        jax.lax.conv_general_dilated(
            x, w[:, :, 3 * i : 3 * (i + 1), :], (1, 1), "SAME",
            dimension_numbers=dn_p,
        )
        for i, x in enumerate(xs)
    ]
    np.testing.assert_allclose(np.asarray(full), np.asarray(sum(parts)),
                               rtol=2e-5, atol=2e-5)


def test_kernelwise_partition_numeric_identity():
    """Eq. 7-8: depthconv(concat(x_i)) == concat(depthconv_i(x_i))."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    xs = [jax.random.normal(ks[i], (1, 8, 8, 2)) for i in range(3)]
    w = jax.random.normal(ks[3], (3, 3, 1, 6))    # depthwise: 6 channels

    dn = jax.lax.conv_dimension_numbers(
        (1, 8, 8, 6), w.shape, ("NHWC", "HWIO", "NHWC")
    )
    full = jax.lax.conv_general_dilated(
        jnp.concatenate(xs, -1), w, (1, 1), "SAME",
        dimension_numbers=dn, feature_group_count=6,
    )
    parts = []
    for i, x in enumerate(xs):
        wi = w[:, :, :, 2 * i : 2 * (i + 1)]
        dn_i = jax.lax.conv_dimension_numbers(
            (1, 8, 8, 2), wi.shape, ("NHWC", "HWIO", "NHWC")
        )
        parts.append(jax.lax.conv_general_dilated(
            x, wi, (1, 1), "SAME", dimension_numbers=dn_i,
            feature_group_count=2,
        ))
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(jnp.concatenate(parts, -1)),
        rtol=2e-5, atol=2e-5,
    )


def test_fused_proj_split_rewrite():
    specs = [
        dict(name="x", op="input", size_bytes=64),
        dict(name="qkv", op="fused_proj", size_bytes=192, preds=[0],
             weight_bytes=1024),
        dict(name="split", op="split", size_bytes=192, preds=[1]),
        dict(name="q_use", op="op", size_bytes=64, preds=[2]),
        dict(name="k_use", op="op", size_bytes=64, preds=[2]),
    ]
    g = Graph.build(specs)
    g2, rep = rewrite_graph(g)
    assert rep.n_fused_proj_split == 1
    assert not any(n.op == "split" for n in g2.nodes)
    assert simulate_schedule(
        g2, g2.topo_order()
    ).peak_bytes <= simulate_schedule(g, g.topo_order()).peak_bytes
