"""Direct tests for the serving stack: plan_decode_arena, decode-state
pack/unpack, the budgeted ArenaPool, and the continuous-batching server.

`launch/serve.py` previously had no dedicated test file; everything here is
tier-1 (tiny smoke configs, a handful of tokens).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import Graph, plan_shared_arena
from repro.core.allocator import resident_bytes
from repro.runtime.pool import ArenaPool, LeaseError, PoolError


# ---------------------------------------------------------------------------
# Synthetic decode-state-shaped graphs (no jax needed for pool tests)
# ---------------------------------------------------------------------------


def state_graph(n_cache: int = 3, cache_bytes: int = 400,
                transient_bytes: int = 1200, name: str = "state") -> Graph:
    """``n_cache`` persistent buffers + a two-node transient chain."""
    specs = [dict(name=f"s{i}", op="cache", size_bytes=cache_bytes, preds=[])
             for i in range(n_cache)]
    specs.append(dict(name="h", op="act", size_bytes=transient_bytes // 2,
                      preds=[]))
    specs.append(dict(name="l", op="act", size_bytes=transient_bytes,
                      preds=[len(specs) - 1]))
    specs.append(dict(name="tok", op="act", size_bytes=4,
                      preds=[len(specs) - 1]))
    return Graph.build(specs, name=name)


# ---------------------------------------------------------------------------
# ArenaPool: admission, queueing, LRU, lease lifecycle
# ---------------------------------------------------------------------------


class TestArenaPool:
    def test_admission_at_exactly_budget(self):
        g = state_graph()
        probe = ArenaPool(1 << 40)
        probe.submit(g)
        probe.submit(g)
        exactly_two = probe.reserved_bytes     # joint extent of two members
        pool = ArenaPool(exactly_two)
        assert pool.submit(g).admitted
        assert pool.submit(g).admitted         # exactly-budget: admits
        assert pool.reserved_bytes == exactly_two
        # one byte less: the second member must queue (it fits an empty
        # pool, so it is queued — not rejected — and drains on release)
        tight = ArenaPool(exactly_two - 1)
        t1, t2 = tight.submit(g), tight.submit(g)
        assert t1.admitted
        assert not t2.admitted and not t2.rejected
        assert tight.queue_len == 1

    def test_reject_when_plan_can_never_fit(self):
        pool = ArenaPool(16)
        t = pool.submit(state_graph())
        assert t.rejected and "budget" in t.reason
        assert pool.stats.rejected == 1 and pool.queue_len == 0

    def test_queue_drains_fifo(self):
        g = state_graph()
        probe = ArenaPool(1 << 40)
        probe.submit(g)
        probe.submit(g)
        per_two = probe.reserved_bytes     # joint extent of two members
        pool = ArenaPool(per_two)
        tickets = [pool.submit(g) for _ in range(5)]
        admitted = [t.admitted for t in tickets]
        assert admitted == [True, True, False, False, False]
        pool.poll()
        order = []
        while any(not t.admitted for t in tickets):
            lease = next(t.lease for t in tickets if t.admitted
                         and t.lease in pool.leases)
            pool.release(lease)
            order += [t.rid for t in pool.poll()]
        # FIFO: rids admitted strictly in submission order
        assert order == sorted(order)

    def test_head_of_line_blocking(self):
        big = state_graph(n_cache=8, name="big")
        small = state_graph(n_cache=1, name="small")
        probe = ArenaPool(1 << 40)
        big_alone = probe._joint_extent([probe.plan(big)[1]])
        # budget fits (big) alone, or (small + small), but not (small + big)
        pool = ArenaPool(big_alone)
        first_small = pool.submit(small)
        assert first_small.admitted
        t_big = pool.submit(big)       # fits an empty pool: queues, no reject
        assert not t_big.rejected and not t_big.admitted
        t_small2 = pool.submit(small)  # would fit right now, but the queued
        assert not t_small2.admitted   # big head must not be jumped
        pool.release(first_small.lease)
        assert t_big.admitted          # head admitted first...
        assert not t_small2.admitted   # ...and small2 still waits behind it
        pool.release(t_big.lease)
        assert t_small2.admitted

    def test_reject_consistent_with_admission_accounting(self):
        # the reject predicate must use the same accounting as admission:
        # a queued request is always admissible into an empty pool, in both
        # overlap modes (otherwise the queue deadlocks behind it)
        g = state_graph()
        for overlap in ("serial", "none"):
            probe = ArenaPool(1 << 40, overlap=overlap)
            alone = probe._joint_extent([probe.plan(g)[1]])
            fits = ArenaPool(alone, overlap=overlap)
            assert fits.submit(g).admitted
            never = ArenaPool(alone - 1, overlap=overlap)
            t1 = never.submit(g)
            t2 = never.submit(g)
            assert t1.rejected and t2.rejected
            assert never.queue_len == 0

    def test_lease_double_free_raises(self):
        pool = ArenaPool(1 << 40)
        t = pool.submit(state_graph())
        pool.release(t.lease)
        with pytest.raises(LeaseError, match="double free"):
            pool.release(t.lease)

    def test_foreign_lease_raises(self):
        pool_a = ArenaPool(1 << 40)
        pool_b = ArenaPool(1 << 40)
        t = pool_a.submit(state_graph())
        with pytest.raises(LeaseError):
            pool_b.release(t.lease)

    def test_plan_lru_and_warm_buffer_lru(self):
        alloc_log = []

        def alloc(n):
            alloc_log.append(n)
            return bytearray(n)

        pool = ArenaPool(1 << 40, max_warm=2, alloc_fn=alloc)
        g = state_graph()
        t1 = pool.submit(g)
        assert pool.stats.plan_hits == 0 and len(alloc_log) == 1
        pool.release(t1.lease)
        t2 = pool.submit(g)            # plan AND buffer reused
        assert pool.stats.plan_hits == 1
        assert pool.stats.warm_hits == 1
        assert len(alloc_log) == 1
        pool.release(t2.lease)
        # eviction: warm capacity 2, three distinct shapes released
        for i in range(3):
            t = pool.submit(state_graph(cache_bytes=404 + 4 * i,
                                        name=f"shape{i}"))
            pool.release(t.lease)
        assert pool.stats.evictions >= 1

    def test_warm_skips_planning_and_allocation(self):
        allocs = []
        pool = ArenaPool(1 << 40, alloc_fn=lambda n: allocs.append(n)
                         or bytearray(n))
        g = state_graph()
        pool.warm(g)
        n_allocs = len(allocs)
        t = pool.submit(g)
        assert t.admitted
        assert pool.stats.plan_hits == 1       # planning skipped
        assert pool.stats.warm_hits == 1       # allocation skipped
        assert len(allocs) == n_allocs

    def test_lease_buffer_covers_resident_extent(self):
        pool = ArenaPool(1 << 40, alloc_fn=lambda n: bytearray(n))
        t = pool.submit(state_graph())
        lease = t.lease
        pbytes, extent = resident_bytes(lease.plan)
        assert lease.persistent_bytes == pbytes == 3 * 400 + 4
        assert len(lease.buffer) == extent == lease.resident_extent

    def test_overlap_modes(self):
        g = state_graph()
        serial = ArenaPool(1 << 40)
        naive = ArenaPool(1 << 40, overlap="none")
        for _ in range(3):
            serial.submit(g)
            naive.submit(g)
        # serial shares the transient slack; naive stacks full arenas
        assert serial.reserved_bytes < naive.reserved_bytes
        sh = serial.shared_plan()
        assert sh.arena_bytes == serial.reserved_bytes
        assert naive.reserved_bytes == 3 * naive.leases[0].arena_bytes
        with pytest.raises(PoolError):
            ArenaPool(1, overlap="bogus")

    def test_scratch_reservation_charges_budget(self):
        g = state_graph()
        one = ArenaPool(1 << 40, overlap="none")
        one.submit(g)
        arena = one.reserved_bytes            # one member's standalone extent
        pool = ArenaPool(2 * arena, overlap="none")
        assert pool.submit(g).admitted
        token = pool.reserve_scratch(arena)
        assert pool.scratch_bytes == arena
        assert pool.reserved_bytes == 2 * arena
        # a second request fits the raw budget but not budget-minus-scratch:
        # it must queue behind the scratch, then drain when it is released
        t = pool.submit(g)
        assert not t.admitted and not t.rejected
        token.release()
        assert t.admitted
        assert pool.reserved_bytes == 2 * arena
        assert pool.stats.peak_reserved_bytes == 2 * arena

    def test_scratch_reservation_over_budget_raises(self):
        g = state_graph()
        pool = ArenaPool(1 << 40, overlap="none")
        pool.submit(g)
        used = pool.reserved_bytes
        pool.budget_bytes = used + 10
        with pytest.raises(PoolError, match="scratch"):
            pool.reserve_scratch(11)
        pool.reserve_scratch(10)               # exactly-fitting is fine
        assert pool.reserved_bytes == used + 10
        with pytest.raises(PoolError, match="negative"):
            pool.reserve_scratch(-1)
        assert pool.scratch_bytes == 10        # failed calls change nothing

    def test_scratch_release_survives_budget_shrink(self):
        # regression: the degradation ladder's rung 2 releases scratch
        # after a shrink may already have left the members alone over
        # budget — releasing the reservation must never raise, else the
        # ladder crashes instead of shedding bytes
        g = state_graph()
        pool = ArenaPool(1 << 40, overlap="none")
        pool.submit(g)
        token = pool.reserve_scratch(64)
        members = pool.reserved_bytes - pool.scratch_bytes
        pool.set_budget(members - 1)
        token.release()                        # releasing succeeds
        assert pool.scratch_bytes == 0
        with pytest.raises(PoolError, match="scratch"):
            pool.reserve_scratch(1)            # reserving is still checked

    def test_independent_scratch_reservers_do_not_clobber(self):
        # regression (PR 10): the absolute-valued reserve_scratch let two
        # independent reservers silently overwrite each other — reserving
        # 100 then 50 left 50 total and the first reserver's bytes were
        # admitted over.  Token-based reservations are additive and each
        # releases only its own bytes.
        pool = ArenaPool(1 << 20, overlap="none")
        t_a = pool.reserve_scratch(100)
        t_b = pool.reserve_scratch(50)
        assert pool.scratch_bytes == 150       # pre-fix: 50 (clobbered)
        t_b.release()
        assert pool.scratch_bytes == 100       # a's bytes survive b's release
        t_a.release()
        assert pool.scratch_bytes == 0

    def test_scratch_double_release_and_foreign_token_raise(self):
        pool = ArenaPool(1 << 20, overlap="none")
        other = ArenaPool(1 << 20, overlap="none")
        token = pool.reserve_scratch(32)
        token.release()
        with pytest.raises(PoolError, match="already released") as ei:
            token.release()
        assert ei.value.code == "scratch_double_release"
        foreign = other.reserve_scratch(8)
        with pytest.raises(PoolError, match="not held") as ei:
            pool.release_scratch(foreign)
        assert ei.value.code == "foreign_scratch"
        assert other.scratch_bytes == 8        # foreign release changed nothing

    def test_scratch_absolute_shim_is_deprecated_but_composes(self):
        # the pre-token API survives as a deprecation shim with its old
        # replace semantics, implemented as one pool-owned token — so it
        # coexists with (and cannot clobber) token-based reservers
        pool = ArenaPool(1 << 20, overlap="none")
        held = pool.reserve_scratch(100)
        with pytest.deprecated_call():
            pool.reserve_scratch_absolute(40)
        assert pool.scratch_bytes == 140
        with pytest.deprecated_call():
            pool.reserve_scratch_absolute(10)  # replaces the 40, not the 100
        assert pool.scratch_bytes == 110
        with pytest.deprecated_call():
            pool.reserve_scratch_absolute(0)   # releases only the legacy slot
        assert pool.scratch_bytes == 100
        held.release()
        assert pool.scratch_bytes == 0


# ---------------------------------------------------------------------------
# plan_decode_arena + decode-state pack/unpack (jax/model-based)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    jax = pytest.importorskip("jax")
    import repro.configs as configs
    from repro.models.zoo import build_model

    cfg = configs.smoke("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestPlanDecodeArena:
    def test_plan_shape_and_regions_layout(self, smoke_model):
        _, model, _ = smoke_model
        from repro.launch.serve import plan_decode_arena

        plan = plan_decode_arena(model, 1, 8)
        assert plan["policy"].startswith("regions+")
        assert plan["persistent_bytes"] + plan["transient_bytes"] \
            == plan["arena_bytes"]
        assert plan["arena_bytes"] < plan["naive_bytes"]
        # caches pinned at the bottom: every cache offset < resident extent
        apl = plan["plan"]
        for i in range(plan["n_cache"]):
            assert apl.offset_of(i) + plan["graph"].sizes[i] \
                <= plan["resident_extent"]
        # transients live strictly above the resident region (the final
        # token node is resident state too — it feeds the next step)
        for nid in range(plan["n_cache"], len(plan["graph"]) - 1):
            assert apl.offset_of(nid) >= plan["resident_extent"]

    def test_plan_cache_hit(self, smoke_model):
        _, model, _ = smoke_model
        from repro.core.plancache import default_cache
        from repro.launch.serve import plan_decode_arena

        p1 = plan_decode_arena(model, 1, 16)
        before = default_cache().stats.hits
        p2 = plan_decode_arena(model, 1, 16)
        assert default_cache().stats.hits == before + 1
        assert p2["plan"] is p1["plan"]       # zero-copy replay
        p3 = plan_decode_arena(model, 1, 24)  # different shape: new plan
        assert p3["arena_bytes"] != p1["arena_bytes"]

    def test_pack_unpack_round_trip(self, smoke_model):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp

        _, model, _ = smoke_model
        from repro.launch.serve import (
            pack_decode_state,
            plan_decode_arena,
            realize_decode_state,
            unpack_decode_state,
        )

        smax = 8
        plan = plan_decode_arena(model, 1, smax)
        cache = model.init_cache(1, smax)
        # fill with recognizable values
        key = jax.random.PRNGKey(42)
        cache = jax.tree.map(
            lambda x: jax.random.normal(key, x.shape, jnp.float32
                                        ).astype(x.dtype), cache)
        arena, rebuilt = realize_decode_state(plan, cache)
        assert arena.dtype == jnp.uint8
        assert arena.shape[0] == plan["resident_extent"]
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # a second pack into the same (donated) buffer round-trips too
        arena2 = pack_decode_state(plan, rebuilt, arena=arena)
        again = unpack_decode_state(plan, arena2, cache)
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(again)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_decode_plan_coresidency_beats_sum(self, smoke_model):
        _, model, _ = smoke_model
        from repro.launch.serve import plan_decode_arena

        plan = plan_decode_arena(model, 1, 8)
        sh = plan_shared_arena([plan["plan"]] * 4)
        assert sh.arena_bytes < sh.sum_member_bytes
        # joint ~= K * persistent + shared transient overlay
        assert sh.arena_bytes >= 4 * plan["persistent_bytes"]
        assert sh.arena_bytes <= 4 * plan["persistent_bytes"] \
            + 4 * plan["transient_bytes"]


# ---------------------------------------------------------------------------
# The continuous-batching server
# ---------------------------------------------------------------------------


class TestDecodeServer:
    GEN = 3
    PROMPT = 4

    def _run(self, smoke_model, n_req, budget_factor, step_mode="serial",
             pooled=True):
        _, model, params = smoke_model
        from repro.launch.serve import (
            plan_decode_arena,
            run_server,
            synth_requests,
        )

        smax = self.PROMPT + self.GEN
        plan = plan_decode_arena(model, 1, smax)
        budget = int(budget_factor * plan["arena_bytes"])
        reqs = synth_requests(n_req, self.PROMPT, self.GEN,
                              model.cfg.vocab_size, seed=3)
        m = run_server(model, params, reqs, smax=smax, budget_bytes=budget,
                       step_mode=step_mode, pooled=pooled, warm=1)
        return reqs, m

    def test_all_requests_complete(self, smoke_model):
        reqs, m = self._run(smoke_model, n_req=4, budget_factor=10)
        assert m["n_served"] == 4 and m["n_rejected"] == 0
        for r in reqs:
            assert len(r.tokens) == self.GEN
            assert r.done_s >= r.submit_s
        assert m["n_tokens"] == 4 * self.GEN

    def test_tight_budget_queues_and_completes(self, smoke_model):
        reqs, m = self._run(smoke_model, n_req=4, budget_factor=1.0)
        assert m["n_served"] == 4
        assert m["max_concurrent"] < 4      # someone had to wait
        assert m["peak_reserved_bytes"] <= m["budget_bytes"]

    def test_vmap_mode_matches_serial(self, smoke_model):
        reqs_s, _ = self._run(smoke_model, n_req=3, budget_factor=10,
                              step_mode="serial")
        reqs_v, m = self._run(smoke_model, n_req=3, budget_factor=10,
                              step_mode="vmap")
        assert [r.tokens for r in reqs_s] == [r.tokens for r in reqs_v]
        # batch of 3 pads to the 4-bucket; the padding row's bytes must be
        # charged to the budget while the step runs
        assert m["peak_reserved_bytes"] >= 4 * m["arena_bytes"]

    def test_vmap_bucket_rounding(self):
        from repro.launch.serve import DecodeServer

        assert [DecodeServer._bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
            [1, 2, 4, 4, 8, 8, 16]

    def test_vmap_falls_back_when_padding_cannot_fit(self, smoke_model):
        # budget for exactly 3 naive arenas: bucket-4 padding cannot be
        # reserved, so the step must run at the exact batch size — same
        # tokens, never over budget
        reqs_s, _ = self._run(smoke_model, n_req=3, budget_factor=10,
                              step_mode="serial")
        reqs_v, m = self._run(smoke_model, n_req=3, budget_factor=3.0,
                              step_mode="vmap")
        assert m["n_served"] == 3
        assert m["peak_reserved_bytes"] <= m["budget_bytes"]
        assert [r.tokens for r in reqs_s] == [r.tokens for r in reqs_v]

    def test_vmap_requires_naive_accounting(self, smoke_model):
        _, model, params = smoke_model
        from repro.launch.serve import DecodeServer, make_pool

        pool = make_pool(1 << 30, step_mode="serial", pooled=True)
        with pytest.raises(ValueError, match="overlap='none'"):
            DecodeServer(model, params, pool, smax=8, step_mode="vmap")

    def test_all_rejected_run_reports_nan_latency(self, smoke_model):
        # regression (PR 10): `lat = sorted(...) or [0.0]` made an
        # all-rejected run report p50/p99 = 0.0 ms, so latency SLOs
        # passed vacuously with zero requests served.  An empty served
        # set must report NaN, which no SLO comparison accepts.
        import math

        _, m = self._run(smoke_model, n_req=3, budget_factor=0.5)
        assert m["n_served"] == 0 and m["n_rejected"] == 3
        assert math.isnan(m["p50_ms"]) and math.isnan(m["p99_ms"])

    def test_pooled_concurrency_beats_naive(self, smoke_model):
        _, m_naive = self._run(smoke_model, n_req=5, budget_factor=1.5,
                               pooled=False)
        _, m_pool = self._run(smoke_model, n_req=5, budget_factor=1.5,
                              pooled=True)
        assert m_pool["max_concurrent"] > m_naive["max_concurrent"]
