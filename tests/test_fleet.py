"""Fleet-layer tests (DESIGN.md §14): open-loop load generator, planner
service, shard router properties, prefill/decode disaggregation,
cross-shard migration bit-exactness, and the chaos-corpus invariants
(no request lost, every shard within its instantaneous budget).

Everything here runs the *simulated* device step — pure byte arithmetic,
no jax — so the whole file is fast enough for the PR lane.
"""

from __future__ import annotations

import math

import numpy as np
import pytest

from repro.runtime.chaos import FaultPlan, FaultSpec
from repro.runtime.fleet import (
    Fleet,
    FleetRequest,
    PlannerService,
    bucket_key_for,
    bucketed_records,
    sim_state_graph,
)
from repro.runtime.loadgen import Arrival, OpenLoopLoadGen, workload_summary
from repro.runtime.pool import PoolError

BUCKETS = (16, 32, 64)


def make_fleet(n_decode=2, n_prefill=0, *, slots=3, buckets=BUCKETS,
               planner=None, **kw):
    """A small fleet whose decode budgets hold ``slots`` mid-bucket plans
    (the largest bucket's plan exceeds one slotless budget only when the
    caller shrinks it — budgets here admit every bucket)."""
    planner = planner or PlannerService()
    records = bucketed_records(planner, buckets)
    budget = slots * records[buckets[-1]].alone_bytes
    fleet = Fleet(planner, key_for=bucket_key_for(records),
                  n_decode=n_decode, n_prefill=n_prefill,
                  shard_budget_bytes=budget, **kw)
    return fleet, records


def short_requests(n, records, *, gen=3, prompt=4, stagger=1, **kw):
    key = records[BUCKETS[0]].key
    return [FleetRequest(rid=i, key=key, prompt_len=prompt, gen_len=gen,
                         arrival_tick=1 + i * stagger, **kw)
            for i in range(n)]


def token_map(fleet):
    return {r.rid: tuple(r.tokens) for r in fleet.done}


# ---------------------------------------------------------------------------
# Open-loop load generator
# ---------------------------------------------------------------------------


class TestLoadGen:
    def test_seeded_determinism(self):
        kw = dict(rate=2.0, latency_frac=0.3,
                  priority_weights={0: 3.0, 1: 1.0},
                  tenant_weights={"a": 1.0, "b": 1.0})
        a = OpenLoopLoadGen(7, **kw).arrivals(500)
        b = OpenLoopLoadGen(7, **kw).arrivals(500)
        assert a == b                      # bit-identical across instances
        c = OpenLoopLoadGen(8, **kw).arrivals(500)
        assert a != c                      # and seed-sensitive

    def test_distribution_shape(self):
        gen = OpenLoopLoadGen(3, rate=4.0, prompt_mean=48.0,
                              prompt_min=2, prompt_max=256,
                              gen_mean=8.0, gen_max=32, latency_frac=0.25)
        arr = gen.arrivals(4000)
        assert [a.rid for a in arr] == list(range(4000))
        ticks = [a.tick for a in arr]
        assert ticks == sorted(ticks) and ticks[0] >= 1
        # Poisson at rate 4/tick: ~4000 arrivals span ~1000 ticks
        assert 800 <= ticks[-1] <= 1250
        prompts = np.array([a.prompt_len for a in arr])
        gens = np.array([a.gen_len for a in arr])
        assert prompts.min() >= 2 and prompts.max() <= 256
        assert gens.min() >= 1 and gens.max() <= 32
        assert 40 <= prompts.mean() <= 56          # lognormal mean ~48
        assert 6 <= gens.mean() <= 10              # geometric mean ~8
        # heavy right tail: p99 well above the mean
        assert np.percentile(prompts, 99) > 2 * prompts.mean()
        lat = sum(a.klass == "latency" for a in arr) / len(arr)
        assert 0.2 <= lat <= 0.3
        assert all(a.klass in ("latency", "memory") for a in arr)

    def test_mixes_and_summary(self):
        gen = OpenLoopLoadGen(1, rate=2.0,
                              priority_weights={0: 1.0, 2: 1.0},
                              tenant_weights={"t0": 3.0, "t1": 1.0})
        arr = gen.arrivals(1000)
        prios = {a.priority for a in arr}
        tenants = [a.tenant for a in arr]
        assert prios == {0, 2}
        assert set(tenants) == {"t0", "t1"}
        assert tenants.count("t0") > 2 * tenants.count("t1")
        s = workload_summary(arr)
        assert s["n"] == 1000 and s["tokens_total"] == \
            sum(a.gen_len for a in arr)
        assert workload_summary([]) == {"n": 0}

    def test_validation(self):
        with pytest.raises(ValueError, match="rate"):
            OpenLoopLoadGen(0, rate=0.0)
        with pytest.raises(ValueError, match="latency_frac"):
            OpenLoopLoadGen(0, latency_frac=1.5)
        with pytest.raises(ValueError, match="prompt bounds"):
            OpenLoopLoadGen(0, prompt_min=5, prompt_max=4)
        with pytest.raises(ValueError, match="weight"):
            OpenLoopLoadGen(0, tenant_weights={"a": -1.0})
        assert OpenLoopLoadGen(0).arrivals(0) == []


# ---------------------------------------------------------------------------
# Planner service
# ---------------------------------------------------------------------------


class TestPlannerService:
    def test_plans_each_graph_once(self):
        svc = PlannerService()
        g = sim_state_graph(16)
        r1 = svc.plan_graph(g)
        r2 = svc.plan_graph(sim_state_graph(16))   # same fingerprint
        assert r1 is r2
        assert svc.stats.planned == 1 and svc.stats.record_hits == 1

    def test_shared_cache_tier(self):
        # two services over one PlanCache: the second rebuilds from the
        # shared tier instead of planning again
        svc1 = PlannerService()
        rec = svc1.plan_graph(sim_state_graph(32))
        svc2 = PlannerService(cache=svc1.cache)
        rec2 = svc2.plan_graph(sim_state_graph(32))
        assert svc2.stats.planned == 0 and svc2.stats.shared_hits == 1
        assert rec2.key == rec.key
        assert rec2.plan.arena_bytes == rec.plan.arena_bytes
        assert [a.offset for a in rec2.plan.allocations] == \
            [a.offset for a in rec.plan.allocations]

    def test_unknown_fingerprint_is_hard_error(self):
        with pytest.raises(KeyError, match="never plan locally"):
            PlannerService().record("deadbeef")

    def test_pareto_classes_derived(self):
        rec = PlannerService().plan_graph(sim_state_graph(16))
        assert set(rec.classes) == {"memory", "latency"}
        assert rec.charge_bytes("memory") == rec.alone_bytes
        assert rec.charge_bytes(None) == rec.alone_bytes
        with pytest.raises(PoolError) as ei:
            rec.plan_for("turbo")
        assert ei.value.code == "unknown_class"

    def test_workers_never_plan_locally(self):
        fleet, records = make_fleet(n_decode=1)
        shard = fleet.shards[0]
        # submitting a graph the planner never registered forces the
        # shard pool onto its planner callback, which must refuse
        with pytest.raises(PoolError) as ei:
            shard.pool.submit(sim_state_graph(128))
        assert ei.value.code == "no_local_planning"


# ---------------------------------------------------------------------------
# Router properties
# ---------------------------------------------------------------------------


class TestRouter:
    def test_least_loaded_spread(self):
        fleet, records = make_fleet(n_decode=4, slots=8)
        reqs = short_requests(8, records, stagger=0)
        for r in reqs:
            fleet.submit(r, now=1)
        per_shard = [s.stats.submitted for s in fleet.shards]
        assert per_shard == [2, 2, 2, 2]   # byte-balanced, deterministic

    def test_placement_never_exceeds_budget(self):
        fleet, records = make_fleet(n_decode=3, slots=2)
        m = fleet.run(short_requests(40, records, gen=4, stagger=1))
        assert m["n_lost"] == 0
        assert m["max_over_budget"] <= 0
        for s in fleet.shards:
            assert s.pool.stats.peak_reserved_bytes <= s.pool.budget_bytes

    def test_oversize_request_rejected_with_budget_code(self):
        fleet, records = make_fleet(n_decode=2, slots=2, buckets=(16, 32))
        huge = records[32]
        # shrink every decode budget below the large plan's charge
        for s in fleet.shards:
            s.pool.set_budget(huge.alone_bytes - 1)
        req = FleetRequest(rid=0, key=huge.key, prompt_len=4, gen_len=2)
        fleet.submit(req, now=1)
        assert req.rejected and req.reject_code == "budget"
        assert "bytes alone" in req.reject_reason

    def test_tenant_quota_rejection(self):
        planner = PlannerService()
        records = bucketed_records(planner, (16,))
        charge = records[16].alone_bytes
        fleet = Fleet(planner, key_for=bucket_key_for(records), n_decode=2,
                      shard_budget_bytes=4 * charge,
                      tenant_quotas={"small": charge - 1})
        req = FleetRequest(rid=0, key=records[16].key, prompt_len=2,
                           gen_len=2, tenant="small")
        fleet.submit(req, now=1)
        assert req.rejected and req.reject_code == "tenant_quota"
        # an unquota'd tenant still lands
        req2 = FleetRequest(rid=1, key=records[16].key, prompt_len=2,
                            gen_len=2, tenant="big")
        fleet.submit(req2, now=1)
        assert not req2.rejected

    def test_all_rejected_fleet_reports_nan_latency(self):
        fleet, records = make_fleet(n_decode=2, slots=2, buckets=(16, 32))
        for s in fleet.shards:
            s.pool.set_budget(1)
        m = fleet.run(short_requests(3, records))
        assert m["n_served"] == 0 and m["n_rejected"] == 3
        assert math.isnan(m["p50_ticks"]) and math.isnan(m["p99_ticks"])


# ---------------------------------------------------------------------------
# End-to-end fleet runs
# ---------------------------------------------------------------------------


class TestFleetRuns:
    def test_open_loop_run_serves_everything(self):
        fleet, records = make_fleet(n_decode=2, slots=4)
        gen = OpenLoopLoadGen(5, rate=1.0, prompt_mean=8.0, prompt_max=30,
                              gen_mean=4.0, gen_max=10, latency_frac=0.25)
        arr = gen.arrivals(120)
        m = fleet.run_arrivals(arr)
        assert m["n_requests"] == 120
        assert m["n_served"] + m["n_rejected"] == 120 and m["n_lost"] == 0
        assert m["n_served"] > 100
        assert m["max_over_budget"] <= 0
        assert m["tokens"] == sum(len(r.tokens) for r in fleet.done)
        assert math.isfinite(m["p99_ticks"])
        # workers fetched every record from the planner, planned nothing
        assert m["planner"]["planned"] == len(BUCKETS)

    def test_tokens_deterministic_across_fleet_shapes(self):
        # the simulated decode is a pure function of (rid, prompt, step):
        # 1-shard and 4-shard fleets must emit identical token streams
        gen = OpenLoopLoadGen(11, rate=1.5, prompt_mean=10.0, prompt_max=40,
                              gen_mean=4.0, gen_max=12)
        arr = gen.arrivals(80)
        outs = []
        for n_decode in (1, 4):
            fleet, _ = make_fleet(n_decode=n_decode, slots=4)
            fleet.run_arrivals(arr)
            outs.append(token_map(fleet))
        assert set(outs[0]) == set(outs[1])
        assert outs[0] == outs[1]

    def test_latency_class_gets_batch_priority(self):
        # oversubscribe one shard: latency-class requests must finish
        # no later than equal-age memory-class ones
        fleet, records = make_fleet(n_decode=1, slots=8, max_batch=2)
        key = records[BUCKETS[0]].key
        reqs = [FleetRequest(rid=i, key=key, prompt_len=2, gen_len=4,
                             klass=("latency" if i % 2 else "memory"),
                             arrival_tick=1)
                for i in range(6)]
        fleet.run(reqs)
        done = {r.rid: r.done_tick for r in fleet.done}
        lat = max(done[i] for i in (1, 3, 5))
        mem = min(done[i] for i in (0, 2, 4))
        assert lat <= mem


# ---------------------------------------------------------------------------
# Prefill/decode disaggregation
# ---------------------------------------------------------------------------


class TestDisaggregation:
    def _workload(self, records, n=16):
        key = records[BUCKETS[-1]].key
        # long prompts (>= the default threshold 2*chunk) + short gens
        return [FleetRequest(rid=i, key=key, prompt_len=40, gen_len=3,
                             arrival_tick=1 + i) for i in range(n)]

    def test_handoff_round_trip_and_stall_removal(self):
        results = {}
        for n_prefill in (0, 1):
            fleet, records = make_fleet(n_decode=2, n_prefill=n_prefill,
                                        slots=4, prefill_chunk=8)
            m = fleet.run(self._workload(records))
            results[n_prefill] = (m, token_map(fleet))
        m0, tok0 = results[0]
        m1, tok1 = results[1]
        assert m0["n_lost"] == m1["n_lost"] == 0
        assert m0["n_served"] == m1["n_served"] == 16
        # inline prefill visibly stalls decode; the lane removes it
        assert m0["prefill_stall_ticks"] > 0 and m0["handoffs"] == 0
        assert m1["handoffs"] == 16 and m1["prefill_stall_ticks"] == 0
        # the handoff is the same host-spill round trip: bit-equal tokens
        assert tok0 == tok1

    def test_short_prompts_skip_the_prefill_lane(self):
        fleet, records = make_fleet(n_decode=2, n_prefill=1, slots=4,
                                    prefill_chunk=8)
        m = fleet.run(short_requests(10, records, prompt=4))
        assert m["handoffs"] == 0
        assert fleet.shards[2].stats.submitted == 0   # prefill shard idle


# ---------------------------------------------------------------------------
# Migration + chaos invariants
# ---------------------------------------------------------------------------


class TestMigrationAndChaos:
    def _workload(self, records, n=24):
        key = records[BUCKETS[0]].key
        return [FleetRequest(rid=i, key=key, prompt_len=4, gen_len=6,
                             arrival_tick=1 + i // 2, priority=i % 2)
                for i in range(n)]

    def test_budget_shrink_migrates_leases_bit_exactly(self):
        # budgets sized in units of the (only) bucket plan, so the shrink
        # bites: 4 slots -> ~1 slot at tick 3
        base, records = make_fleet(n_decode=2, slots=4, buckets=(16,))
        base.run(self._workload(records))
        base_tok = token_map(base)

        # shard 0's budget collapses below one plan at tick 3: its members
        # must spill and can only re-enter on shard 1 (a migration)
        plan = FaultPlan([FaultSpec("budget_shrink", 3, 0.05)])
        fleet, records = make_fleet(n_decode=2, slots=4, buckets=(16,),
                                    fault_plans={0: plan})
        m = fleet.run(self._workload(records))
        assert m["n_lost"] == 0
        assert m["preemptions"] > 0
        assert m["migrations"] > 0                 # crossed shards
        assert m["max_over_budget"] <= 0
        migrated = [r for r in fleet.done if r.migrations > 0]
        assert migrated
        assert all(len(set(r.shards)) > 1 for r in migrated)
        # served streams bit-equal the fault-free twin, migrations and all
        for rid, toks in token_map(fleet).items():
            assert toks == base_tok[rid]

    def test_chaos_corpus_invariants(self):
        # generated fault scripts on every shard: across the corpus, no
        # request is ever lost, no shard ever exceeds its instantaneous
        # budget, and surviving token streams bit-equal the fault-free run
        base, records = make_fleet(n_decode=2, slots=3)
        base.run(self._workload(records))
        base_tok = token_map(base)
        for seed in range(6):
            plans = {sid: FaultPlan.generate(seed + 17 * sid, n_ticks=10,
                                             rate=0.35)
                     for sid in range(2)}
            fleet, records = make_fleet(n_decode=2, slots=3,
                                        fault_plans=plans)
            m = fleet.run(self._workload(records))
            ctx = f"seed={seed}: " + "; ".join(
                p.describe() for p in plans.values())
            assert m["n_lost"] == 0, ctx
            assert m["n_served"] + m["n_rejected"] == m["n_requests"], ctx
            assert m["max_over_budget"] <= 0, ctx
            for rid, toks in token_map(fleet).items():
                assert toks == base_tok[rid], ctx

    def test_readmit_exhaustion_is_a_rejection_not_a_loss(self):
        # a hard budget shrink mid-run spills admitted leases; with every
        # re-admission blocked, the retries must exhaust into clean
        # rejections (never a lost request, never an infinite loop)
        fleet, records = make_fleet(n_decode=1, slots=4,
                                    max_readmit_attempts=2)
        shard = fleet.shards[0]
        reqs = [FleetRequest(rid=i, key=records[BUCKETS[0]].key,
                             prompt_len=4, gen_len=6, arrival_tick=1)
                for i in range(3)]
        orig_tick = shard.tick

        def tick(now, fl):
            if now == 2:     # shrink hard, then fault all re-admission
                shard.set_budget(1, fl, now)
                shard.pool.admission_hook = lambda: True
            orig_tick(now, fl)

        shard.tick = tick
        m = fleet.run(reqs)
        assert m["n_lost"] == 0
        assert fleet.rejected
        assert all(r.reject_code in ("readmit_exhausted", "budget")
                   for r in fleet.rejected)
