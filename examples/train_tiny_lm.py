"""End-to-end training driver: a ~100M-parameter llama-family model on the
synthetic pipeline, with checkpoint/restart and straggler accounting.

    PYTHONPATH=src python examples/train_tiny_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_tiny_lm.py --preset 25m  --steps 120

(CPU container note: the 100m preset is the assignment's "train ~100M model"
driver; the 25m preset covers quick verification.  Both exercise the same
code path as launch/train.py on a TPU mesh.)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.data import DataPipeline
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.zoo import build_model
from repro.runtime import FaultTolerantLoop

PRESETS = {
    # ~104M params: 10L x d640 x ff2560, 32k vocab
    "100m": dict(n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32_000, batch=4,
                 seq=256),
    # ~26M params for quick runs
    "25m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=3,
                head_dim=64, d_ff=1536, vocab_size=8_192, batch=4,
                seq=128),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="25m")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    batch_size, seq = p.pop("batch"), p.pop("seq")
    cfg = dataclasses.replace(
        C.get("llama3.2-1b"), name=f"llama-{args.preset}", **p
    )
    model = build_model(cfg)
    opt = make_optimizer(cfg, lr=args.lr)
    step_fn = jax.jit(
        make_train_step(model, opt, None, peak_lr=args.lr,
                        warmup=args.steps // 10, total_steps=args.steps),
        donate_argnums=(0,),
    )

    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
          f"{batch_size}x{seq} tokens/step")
    state = {"params": params, "opt": opt.init(params)}

    pipe = DataPipeline(cfg=cfg, seq_len=seq, global_batch=batch_size)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start = latest_step(args.ckpt_dir) or 0
    if start:
        print(f"[train] resuming from step {start}")
        state = restore(args.ckpt_dir, start, state)

    losses = []
    t0 = time.perf_counter()

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            dt = time.perf_counter() - t0
            print(f"[train] step {step:4d} loss {losses[-1]:.4f} "
                  f"({step * batch_size * seq / max(dt, 1e-9):.0f} tok/s)")

    loop = FaultTolerantLoop(
        step_fn=lambda s, b: step_fn(
            s, {k: jnp.asarray(v) for k, v in b.items()}
        ),
        ckpt_manager=ckpt,
        batch_iter_factory=pipe.iter_from,
        ckpt_every=max(args.steps // 4, 25),
    )
    state, end = loop.run(state, start, args.steps, on_metrics=on_metrics)
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"[train] finished step {end}: loss {first:.4f} -> {last:.4f} "
          f"(improved: {last < first})")


if __name__ == "__main__":
    main()
