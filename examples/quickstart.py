"""Quickstart: SERENITY in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Schedule an irregularly wired cell for minimal peak activation memory.
2. Rewrite concat+conv patterns and re-schedule (paper Fig. 9).
3. Execute the schedule on the planned arena: every intermediate is a slice
   of one buffer, and the realized footprint is *measured* equal to the plan.
4. Apply the same scheduler to a JAX function's jaxpr (framework feature).
"""

import jax
import jax.numpy as jnp

from repro.core import PlanConfig, execute, plan
from repro.core.jax_bridge import serenity_transform
from repro.graphs import swiftnet_cell


def main() -> None:
    # -- 1/2: the paper's pipeline on an edge-style NAS cell ----------------
    g = swiftnet_cell("A")
    plain = plan(g, PlanConfig(rewrite=False))
    rew = plan(g, PlanConfig(rewrite=True))
    kahn = plain.baseline_peaks["kahn"]
    print(f"SwiftNet cell A ({len(g)} nodes)")
    print(f"  TFLite-order peak : {kahn/1024:8.1f} KB")
    print(f"  SERENITY schedule : {plain.peak_bytes/1024:8.1f} KB "
          f"({kahn/plain.peak_bytes:.2f}x)")
    print(f"  + graph rewriting : {rew.peak_bytes/1024:8.1f} KB "
          f"({kahn/rew.peak_bytes:.2f}x)")
    print(f"  arena (allocator) : {rew.arena_bytes/1024:8.1f} KB")

    # -- 3: run the schedule against the planned arena ----------------------
    ex = execute(rew.graph, inputs=None, plan=rew.arena, order=rew.order)
    print(f"  executed on arena : realized peak "
          f"{ex.realized_peak_bytes/1024:8.1f} KB "
          f"(== planned: {ex.realized_matches_plan})")

    # -- 4: the same optimization on a JAX computation -----------------------
    def nas_like(x):
        branches = []
        for i in range(6):
            h = jnp.tanh(x * (i + 1.0))
            h = h @ jnp.ones((x.shape[-1], 4 * x.shape[-1]), x.dtype)
            h = jax.nn.relu(h) @ jnp.ones((4 * x.shape[-1], 16), x.dtype)
            branches.append(h)
        return jnp.sum(jnp.concatenate(branches, -1) ** 2)

    x = jnp.ones((64, 128), jnp.float32)
    fn = serenity_transform(nas_like)
    y = jax.jit(fn)(x)
    r = fn.report
    print("\njaxpr scheduling (same algorithm, one level down):")
    print(f"  {r.n_eqns} equations; traced-order live peak "
          f"{r.original_peak/1024:.0f} KB -> {r.optimal_peak/1024:.0f} KB "
          f"({r.reduction_vs_original:.2f}x), output preserved: "
          f"{bool(jnp.allclose(y, nas_like(x)))}")


if __name__ == "__main__":
    main()
