"""Paper walk-through: schedule every evaluation network end to end,
including the adaptive soft-budget trajectory and the Belady off-chip
traffic sweep (Figs. 8, 10, 11).

    PYTHONPATH=src python examples/schedule_edge_network.py
"""

from repro.core import PlanConfig, plan, simulate_traffic
from repro.graphs import BENCHMARK_GRAPHS


def main() -> None:
    for name, fn in BENCHMARK_GRAPHS.items():
        g = fn()
        res = plan(g, PlanConfig(rewrite=True, state_quota=4000))
        kahn = res.baseline_peaks["kahn"]
        print(f"\n=== {name} ({len(g)} nodes -> {len(res.graph)} after "
              f"rewriting, {len(res.segments)} segments)")
        print(f"  peak: kahn {kahn/1024:.0f} KB -> serenity "
              f"{res.peak_bytes/1024:.0f} KB ({kahn/res.peak_bytes:.2f}x); "
              f"arena {res.arena_bytes/1024:.0f} KB; "
              f"sched time {res.wall_time_s*1e3:.1f} ms")
        for st in res.budget_stats:
            traj = " -> ".join(f"{t//1024}KB:{f}" for t, f in
                               st.tau_trajectory)
            print(f"  soft-budget trajectory: {traj}")
        cap = res.peak_bytes
        t = simulate_traffic(res.graph, res.order, cap,
                             include_weights=False)
        print(f"  off-chip traffic at {cap//1024} KB on-chip: "
              f"{t.total_bytes//1024} KB "
              f"({'fits entirely' if t.fits_entirely else 'spills'})")


if __name__ == "__main__":
    main()
