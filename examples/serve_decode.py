"""Batched serving example: prefill + greedy decode on the SERENITY
arena-*realized* decode state.

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-2b

The driver plans the decode-state arena with the paper's offset allocator,
packs the initial KV/recurrent state into one buffer at the planned byte
offsets, rebuilds the state from arena slices, and measures the realized
footprint against the plan before decoding (see ``repro.launch.serve``,
DESIGN.md §1/§6).  Uses the reduced (smoke) config of any assigned
architecture so it runs on CPU; the identical driver serves the full config
on a TPU mesh (launch/serve.py --mesh single).
"""

import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    if "--smoke" not in sys.argv:
        sys.argv.append("--smoke")
    if not any(a.startswith("--arch") for a in sys.argv):
        sys.argv += ["--arch", "llama3.2-1b"]
    serve_main()
