"""Multi-tenant serving example: a request queue decoding over leased,
arena-planned KV state under one byte budget.

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-2b

The driver plans each request's decode-state arena with the paper's offset
allocator (KV caches pinned resident, per-step transients above), leases it
from a budgeted ``repro.runtime.ArenaPool`` (admit / queue / reject against
the joint co-residency extent), and continuously batches the decode across
admitted requests — each request's state packed in its leased buffer at the
planned byte offsets between steps (``repro.launch.serve``, DESIGN.md
§1/§9).  Uses the reduced (smoke) config of any assigned architecture so it
runs on CPU; the identical driver serves the full config on a TPU mesh
(launch/serve.py --mesh single).
"""

import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    if "--smoke" not in sys.argv:
        sys.argv.append("--smoke")
    if not any(a.startswith("--arch") for a in sys.argv):
        sys.argv += ["--arch", "llama3.2-1b"]
    serve_main()
