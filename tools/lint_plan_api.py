"""API tripwire: the PlanConfig surface is the only way to plan.

    python tools/lint_plan_api.py

``serenity.plan(graph, PlanConfig(...))`` is the planning entry point
(DESIGN.md §10).  The legacy entry points — ``schedule(...)``,
``schedule_order(...)`` — and the legacy per-call kwargs
(``beam_fallback=``, planning ``**schedule_kw`` on ``execute`` /
``plan_coresidency``) survive only as deprecation shims for out-of-tree
callers.  In-tree code must not use them: this lint greps ``src``,
``benchmarks`` and ``examples`` and fails the build on any hit, so a
deprecated call can never creep back in behind the shims' warnings.

``tests`` are exempt (they exercise the shims on purpose), as are the two
modules that *define* the shims.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples")
# the shims have to name themselves; everything else goes through plan()
ALLOWLIST = {
    "src/repro/core/serenity.py",
    "src/repro/core/jax_bridge.py",
}

# a *call* of a deprecated entry point: the name not preceded by an
# identifier character or a dot (so `dp_schedule(`, `kahn_schedule(` and
# attribute access stay legal) and glued to its paren (so prose like
# "Kahn's schedule (always feasible)" in docstrings doesn't trip)
_DEPRECATED_CALL = re.compile(
    r"(?<![A-Za-z0-9_.])(schedule|schedule_order)\(")
# kwargs that only exist on the deprecated surface
_DEPRECATED_KWARG = re.compile(r"(?<![A-Za-z0-9_])beam_fallback\s*=")


def _code_lines(path: pathlib.Path):
    """Yield (lineno, line) with comment tails stripped.

    Line-based on purpose: a lint that needs the AST to explain itself has
    already lost the "greppable" property this tripwire is for.  Comment
    stripping is naive (a ``#`` inside a string literal truncates the
    line), which can only *hide* a violation inside such a string — and a
    deprecated call smuggled into a string is not a call.
    """
    for i, raw in enumerate(path.read_text().splitlines(), 1):
        yield i, raw.split("#", 1)[0]


def main() -> int:
    errors = []
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            rel = path.relative_to(ROOT).as_posix()
            if rel in ALLOWLIST:
                continue
            for lineno, line in _code_lines(path):
                m = _DEPRECATED_CALL.search(line)
                if m and not line.lstrip().startswith("def "):
                    errors.append(
                        f"{rel}:{lineno}: calls deprecated `{m.group(1)}(`"
                        f" — use serenity.plan(graph, PlanConfig(...))")
                if _DEPRECATED_KWARG.search(line):
                    errors.append(
                        f"{rel}:{lineno}: deprecated kwarg `beam_fallback=`"
                        f" — use PlanConfig(on_timeout=...)")
    for e in errors:
        print(f"::error::{e}")
    n_files = sum(len(list((ROOT / d).rglob("*.py"))) for d in SCAN_DIRS)
    print(f"lint_plan_api: {n_files} files scanned, {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
