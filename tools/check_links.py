"""Docs tripwire: validate markdown links and DESIGN.md section anchors.

    python tools/check_links.py

Checks, without any network access:

  * every relative markdown link ``[text](path)`` in the repo's top-level
    ``*.md`` files points at an existing file (anchors stripped; http(s)
    and mailto links are skipped — external availability is not this
    script's business);
  * every ``DESIGN.md §N`` citation — in the markdown files *and* in
    ``src``/``benchmarks``/``examples``/``tests`` Python sources — resolves
    to an actual ``## §N`` heading in DESIGN.md, so renumbering a section
    without fixing its citations fails CI.

Exits non-zero on the first class of rot it finds.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
MD_FILES = sorted(ROOT.glob("*.md"))
PY_DIRS = ("src", "benchmarks", "examples", "tests", "tools")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SECTION_REF = re.compile(r"DESIGN\.md\s+§(\d+)")
_SECTION_DEF = re.compile(r"^##\s+§(\d+)\b", re.M)


def check_markdown_links() -> list[str]:
    errors = []
    for md in MD_FILES:
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                errors.append(f"{md.name}: broken link -> {target}")
    return errors


def check_design_section_refs() -> list[str]:
    design = ROOT / "DESIGN.md"
    defined = set(_SECTION_DEF.findall(design.read_text()))
    errors = []
    sources = list(MD_FILES)
    for d in PY_DIRS:
        sources += sorted((ROOT / d).rglob("*.py"))
    for src in sources:
        for num in _SECTION_REF.findall(src.read_text()):
            if num not in defined:
                errors.append(
                    f"{src.relative_to(ROOT)}: cites DESIGN.md §{num}, "
                    f"which does not exist (sections: "
                    f"{', '.join(sorted(defined))})")
    return errors


def main() -> int:
    errors = check_markdown_links() + check_design_section_refs()
    for e in errors:
        print(f"::error::{e}")
    print(f"check_links: {len(MD_FILES)} markdown files, "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
